//! 8-lane f32 microkernels for the `linalg` hot paths.
//!
//! The inner loops of the matmul family, the elementwise family, the
//! reduction family, the MGS trailing-column projection, and the Jacobi
//! rotation phases all funnel through this module. Three instantiations
//! of every kernel exist:
//!
//! * **scalar** ([`scalar`]) — the historical loops, always compiled,
//!   bit-for-bit the pre-SIMD behavior. The default dispatch target when
//!   the `simd` cargo feature is off.
//! * **portable lanes** — the same kernel tiled over a `[f32; 8]` lane
//!   struct ([`F32x8`]); plain Rust, compiles on every target.
//! * **AVX2** — `#[target_feature(enable = "avx2")]` instantiations of
//!   the *identical* lane code on `x86_64`, picked at runtime via CPU
//!   detection. Only vertical 256-bit ops are generated (no FMA
//!   contraction), so the AVX2 and portable instantiations are **bitwise
//!   identical** — the feature setting alone determines the numbers, the
//!   host CPU only the speed.
//!
//! # Dispatch
//!
//! With the `simd` feature off every public kernel compiles straight to
//! its scalar body (the `cfg!` test is a compile-time constant — zero
//! dispatch cost). With the feature on, kernels take the lane path unless
//! the computation runs under [`with_scalar`], the baseline hook used by
//! the fig3 speedup bench and `tests/simd_parity.rs`. The force-scalar
//! flag lives in `pool::context()` bit 0, so it follows fanned-out work
//! into pool workers exactly like the width override — a forced-scalar
//! measurement can never silently mix SIMD tiles on helper threads.
//!
//! # Determinism contract
//!
//! * **Vertical kernels** (axpy, scale/add/sub/ema, normalize, sq_accum,
//!   both rotation kernels, and the packed matmul tiles) perform the same
//!   float ops per element in the same order as the scalar loops — they
//!   are bitwise identical to scalar at every pool width.
//! * **Horizontal reductions** (dot, sum, sum_sq, sse_about) regroup the
//!   accumulation into a fixed shape: two 8-lane accumulators over
//!   16-element stripes, combined as `(acc0 + acc1)` through the fixed
//!   lane tree `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))`, plus an in-order
//!   scalar tail. The shape depends only on the input length — never the
//!   pool width or the host CPU — so the SIMD path is bitwise
//!   reproducible at a given feature setting, while scalar↔simd drift is
//!   ulp-bounded (pinned by `tests/simd_parity.rs`). `max_abs` regroups
//!   too, but max is order-insensitive, so its result never changes.
//! * Dispatch is per-computation, not per-element: a single kernel call
//!   never mixes scalar and lane arithmetic.

use crate::util::pool;

/// `pool::context()` bit claimed by [`with_scalar`].
const FORCE_SCALAR: u32 = 1 << 0;

/// k-block edge of the packed matmul microkernel (mirrors the cache
/// blocking of the scalar kernel in `linalg::mat`).
const KC: usize = 64;

/// Whether the `simd` feature is compiled in at all (bench reporting).
pub fn compiled() -> bool {
    cfg!(feature = "simd")
}

/// Whether kernels currently dispatch to the lane path: requires the
/// `simd` feature and no enclosing [`with_scalar`].
pub fn active() -> bool {
    cfg!(feature = "simd") && (pool::context() & FORCE_SCALAR) == 0
}

/// Run `f` with every kernel pinned to the scalar path — the baseline
/// hook for speedup measurements and parity tests. Scoped and re-entrant;
/// the flag follows `f`'s parallel regions into pool workers.
pub fn with_scalar<R>(f: impl FnOnce() -> R) -> R {
    pool::with_context(pool::context() | FORCE_SCALAR, f)
}

/// Whether the runtime AVX2 instantiations are in play (bench reporting —
/// the portable lane path is used when this is false).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        avx2()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2() -> bool {
    std::is_x86_feature_detected!("avx2")
}

// --------------------------------------------------------------- lanes ---

/// Portable 8-lane f32 vector. All ops are per-lane and `inline(always)`,
/// so the AVX2 instantiations compile them to single 256-bit instructions
/// while every other target gets the autovectorizer's best.
#[derive(Clone, Copy)]
struct F32x8([f32; 8]);

impl F32x8 {
    const ZERO: F32x8 = F32x8([0.0; 8]);

    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32x8([v; 8])
    }

    /// Load the first 8 elements of `s` (caller guarantees `s.len() >= 8`).
    #[inline(always)]
    fn load(s: &[f32]) -> Self {
        let mut l = [0.0; 8];
        l.copy_from_slice(&s[..8]);
        F32x8(l)
    }

    /// Load up to 8 elements, zero-filling the missing lanes.
    #[inline(always)]
    fn load_partial(s: &[f32]) -> Self {
        let mut l = [0.0; 8];
        l[..s.len()].copy_from_slice(s);
        F32x8(l)
    }

    #[inline(always)]
    fn store(self, d: &mut [f32]) {
        d[..8].copy_from_slice(&self.0);
    }

    /// Store only the first `d.len()` lanes.
    #[inline(always)]
    fn store_partial(self, d: &mut [f32]) {
        let w = d.len();
        d.copy_from_slice(&self.0[..w]);
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(o.0) {
            *a += b;
        }
        F32x8(r)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(o.0) {
            *a -= b;
        }
        F32x8(r)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(o.0) {
            *a *= b;
        }
        F32x8(r)
    }

    #[inline(always)]
    fn div(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(o.0) {
            *a /= b;
        }
        F32x8(r)
    }

    #[inline(always)]
    fn abs(self) -> Self {
        let mut r = self.0;
        for a in r.iter_mut() {
            *a = a.abs();
        }
        F32x8(r)
    }

    #[inline(always)]
    fn max(self, o: Self) -> Self {
        let mut r = self.0;
        for (a, b) in r.iter_mut().zip(o.0) {
            *a = a.max(b);
        }
        F32x8(r)
    }

    /// Horizontal sum through the fixed lane tree
    /// `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))` — part of the determinism
    /// contract: the reduction shape never depends on anything but this
    /// constant.
    #[inline(always)]
    fn hsum(self) -> f32 {
        let a = self.0;
        let s04 = a[0] + a[4];
        let s15 = a[1] + a[5];
        let s26 = a[2] + a[6];
        let s37 = a[3] + a[7];
        (s04 + s15) + (s26 + s37)
    }

    /// Horizontal max of non-negative lanes.
    #[inline(always)]
    fn hmax(self) -> f32 {
        self.0.iter().fold(0.0f32, |m, &v| m.max(v))
    }
}

// ------------------------------------------------------ scalar kernels ---

/// The historical scalar kernels — always compiled, bit-for-bit the
/// pre-SIMD loops. Public so the fig3 bench and `tests/simd_parity.rs`
/// can pin the lane path against them inside one binary; runtime forcing
/// of whole computations goes through [`with_scalar`] instead.
pub mod scalar {
    pub fn dot(x: &[f32], y: &[f32]) -> f32 {
        x.iter().zip(y).map(|(a, b)| a * b).sum()
    }

    pub fn sum(x: &[f32]) -> f32 {
        x.iter().sum()
    }

    pub fn sum_sq(x: &[f32]) -> f32 {
        x.iter().map(|&v| v * v).sum()
    }

    /// Sum of squared deviations about `mean`.
    pub fn sse_about(x: &[f32], mean: f32) -> f32 {
        x.iter().map(|&v| (v - mean) * (v - mean)).sum()
    }

    pub fn max_abs(x: &[f32]) -> f32 {
        x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// dst += a * src.
    pub fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
        for (d, s) in dst.iter_mut().zip(src) {
            *d += a * s;
        }
    }

    /// out = src * s.
    pub fn scale_into(out: &mut [f32], src: &[f32], s: f32) {
        for (o, x) in out.iter_mut().zip(src) {
            *o = x * s;
        }
    }

    /// out = a + b.
    pub fn add_into(out: &mut [f32], a: &[f32], b: &[f32]) {
        for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
            *o = x + y;
        }
    }

    /// out = a - b.
    pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
        for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
            *o = x - y;
        }
    }

    /// dst = a * dst + b * src.
    pub fn ema(dst: &mut [f32], a: f32, src: &[f32], b: f32) {
        for (x, y) in dst.iter_mut().zip(src) {
            *x = a * *x + b * y;
        }
    }

    /// dst = (dst - mean) / std.
    pub fn normalize(dst: &mut [f32], mean: f32, std: f32) {
        for x in dst.iter_mut() {
            *x = (*x - mean) / std;
        }
    }

    /// acc += row * row, elementwise.
    pub fn sq_accum(acc: &mut [f32], row: &[f32]) {
        for (o, &x) in acc.iter_mut().zip(row) {
            *o += x * x;
        }
    }

    /// Rotate the slice pair: rp' = c*rp - s*rq, rq' = s*rp + c*rq.
    pub fn rot2(rp: &mut [f32], rq: &mut [f32], c: f32, s: f32) {
        for (p, q) in rp.iter_mut().zip(rq.iter_mut()) {
            let (wp, wq) = (*p, *q);
            *p = c * wp - s * wq;
            *q = s * wp + c * wq;
        }
    }

    /// C += A @ B over contiguous row-major slices (`c.len() / n` rows,
    /// `a` rows x k, `b` k x n), with ascending-k per-element
    /// accumulation and the blocked kernel's zero-skip — the scalar twin
    /// of the packed microkernel, behind [`super::matmul_into`].
    pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
        for (crow, arow) in c.chunks_mut(n).zip(a.chunks(k)) {
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                for (cv, &bv) in crow.iter_mut().zip(&b[kk * n..(kk + 1) * n]) {
                    *cv += av * bv;
                }
            }
        }
    }

    /// Apply one Jacobi round's column rotations to a row-major block
    /// (`rows.len() / n` rows): the historical row-outer / pair-inner
    /// order. Pairs are disjoint within a round, so every loop order
    /// writes the same bits.
    pub fn rot_cols_block(
        rows: &mut [f32],
        n: usize,
        pairs: &[(usize, usize)],
        rot: &[Option<(f32, f32)>],
    ) {
        for row in rows.chunks_mut(n) {
            for (t, r) in rot.iter().enumerate() {
                if let Some((c, s)) = *r {
                    let (p, q) = pairs[t];
                    let xp = row[p];
                    let xq = row[q];
                    row[p] = c * xp - s * xq;
                    row[q] = s * xp + c * xq;
                }
            }
        }
    }
}

// -------------------------------------------------------- lane kernels ---

#[inline(always)]
fn dot_lanes(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let mut acc0 = F32x8::ZERO;
    let mut acc1 = F32x8::ZERO;
    let mut i = 0;
    while i + 16 <= n {
        acc0 = acc0.add(F32x8::load(&x[i..]).mul(F32x8::load(&y[i..])));
        acc1 = acc1.add(F32x8::load(&x[i + 8..]).mul(F32x8::load(&y[i + 8..])));
        i += 16;
    }
    if i + 8 <= n {
        acc0 = acc0.add(F32x8::load(&x[i..]).mul(F32x8::load(&y[i..])));
        i += 8;
    }
    let mut tail = 0.0f32;
    for (a, b) in x[i..].iter().zip(&y[i..]) {
        tail += a * b;
    }
    acc0.add(acc1).hsum() + tail
}

#[inline(always)]
fn sum_lanes(x: &[f32]) -> f32 {
    let mut acc0 = F32x8::ZERO;
    let mut acc1 = F32x8::ZERO;
    let mut it = x.chunks_exact(16);
    for pair in it.by_ref() {
        acc0 = acc0.add(F32x8::load(&pair[..8]));
        acc1 = acc1.add(F32x8::load(&pair[8..]));
    }
    let mut rest = it.remainder();
    if rest.len() >= 8 {
        acc0 = acc0.add(F32x8::load(rest));
        rest = &rest[8..];
    }
    let mut tail = 0.0f32;
    for &v in rest {
        tail += v;
    }
    acc0.add(acc1).hsum() + tail
}

#[inline(always)]
fn sum_sq_lanes(x: &[f32]) -> f32 {
    let mut acc0 = F32x8::ZERO;
    let mut acc1 = F32x8::ZERO;
    let mut it = x.chunks_exact(16);
    for pair in it.by_ref() {
        let a = F32x8::load(&pair[..8]);
        let b = F32x8::load(&pair[8..]);
        acc0 = acc0.add(a.mul(a));
        acc1 = acc1.add(b.mul(b));
    }
    let mut rest = it.remainder();
    if rest.len() >= 8 {
        let a = F32x8::load(rest);
        acc0 = acc0.add(a.mul(a));
        rest = &rest[8..];
    }
    let mut tail = 0.0f32;
    for &v in rest {
        tail += v * v;
    }
    acc0.add(acc1).hsum() + tail
}

#[inline(always)]
fn sse_about_lanes(x: &[f32], mean: f32) -> f32 {
    let mv = F32x8::splat(mean);
    let mut acc0 = F32x8::ZERO;
    let mut acc1 = F32x8::ZERO;
    let mut it = x.chunks_exact(16);
    for pair in it.by_ref() {
        let a = F32x8::load(&pair[..8]).sub(mv);
        let b = F32x8::load(&pair[8..]).sub(mv);
        acc0 = acc0.add(a.mul(a));
        acc1 = acc1.add(b.mul(b));
    }
    let mut rest = it.remainder();
    if rest.len() >= 8 {
        let a = F32x8::load(rest).sub(mv);
        acc0 = acc0.add(a.mul(a));
        rest = &rest[8..];
    }
    let mut tail = 0.0f32;
    for &v in rest {
        tail += (v - mean) * (v - mean);
    }
    acc0.add(acc1).hsum() + tail
}

#[inline(always)]
fn max_abs_lanes(x: &[f32]) -> f32 {
    let mut m = F32x8::ZERO;
    let mut it = x.chunks_exact(8);
    for c in it.by_ref() {
        m = m.max(F32x8::load(c).abs());
    }
    let mut r = m.hmax();
    for &v in it.remainder() {
        r = r.max(v.abs());
    }
    r
}

#[inline(always)]
fn axpy_lanes(dst: &mut [f32], a: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let av = F32x8::splat(a);
    let n8 = dst.len() - dst.len() % 8;
    let mut i = 0;
    while i < n8 {
        let d = F32x8::load(&dst[i..]).add(av.mul(F32x8::load(&src[i..])));
        d.store(&mut dst[i..]);
        i += 8;
    }
    for (d, s) in dst[n8..].iter_mut().zip(&src[n8..]) {
        *d += a * s;
    }
}

#[inline(always)]
fn scale_into_lanes(out: &mut [f32], src: &[f32], s: f32) {
    debug_assert_eq!(out.len(), src.len());
    let sv = F32x8::splat(s);
    let n8 = out.len() - out.len() % 8;
    let mut i = 0;
    while i < n8 {
        F32x8::load(&src[i..]).mul(sv).store(&mut out[i..]);
        i += 8;
    }
    for (o, x) in out[n8..].iter_mut().zip(&src[n8..]) {
        *o = x * s;
    }
}

#[inline(always)]
fn add_into_lanes(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let n8 = out.len() - out.len() % 8;
    let mut i = 0;
    while i < n8 {
        F32x8::load(&a[i..]).add(F32x8::load(&b[i..])).store(&mut out[i..]);
        i += 8;
    }
    for ((o, x), y) in out[n8..].iter_mut().zip(&a[n8..]).zip(&b[n8..]) {
        *o = x + y;
    }
}

#[inline(always)]
fn sub_into_lanes(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let n8 = out.len() - out.len() % 8;
    let mut i = 0;
    while i < n8 {
        F32x8::load(&a[i..]).sub(F32x8::load(&b[i..])).store(&mut out[i..]);
        i += 8;
    }
    for ((o, x), y) in out[n8..].iter_mut().zip(&a[n8..]).zip(&b[n8..]) {
        *o = x - y;
    }
}

#[inline(always)]
fn ema_lanes(dst: &mut [f32], a: f32, src: &[f32], b: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let av = F32x8::splat(a);
    let bv = F32x8::splat(b);
    let n8 = dst.len() - dst.len() % 8;
    let mut i = 0;
    while i < n8 {
        let d = av.mul(F32x8::load(&dst[i..])).add(bv.mul(F32x8::load(&src[i..])));
        d.store(&mut dst[i..]);
        i += 8;
    }
    for (x, y) in dst[n8..].iter_mut().zip(&src[n8..]) {
        *x = a * *x + b * y;
    }
}

#[inline(always)]
fn normalize_lanes(dst: &mut [f32], mean: f32, std: f32) {
    let mv = F32x8::splat(mean);
    let sv = F32x8::splat(std);
    let n8 = dst.len() - dst.len() % 8;
    let mut i = 0;
    while i < n8 {
        F32x8::load(&dst[i..]).sub(mv).div(sv).store(&mut dst[i..]);
        i += 8;
    }
    for x in dst[n8..].iter_mut() {
        *x = (*x - mean) / std;
    }
}

#[inline(always)]
fn sq_accum_lanes(acc: &mut [f32], row: &[f32]) {
    debug_assert_eq!(acc.len(), row.len());
    let n8 = acc.len() - acc.len() % 8;
    let mut i = 0;
    while i < n8 {
        let r = F32x8::load(&row[i..]);
        F32x8::load(&acc[i..]).add(r.mul(r)).store(&mut acc[i..]);
        i += 8;
    }
    for (o, &x) in acc[n8..].iter_mut().zip(&row[n8..]) {
        *o += x * x;
    }
}

#[inline(always)]
fn rot2_lanes(rp: &mut [f32], rq: &mut [f32], c: f32, s: f32) {
    debug_assert_eq!(rp.len(), rq.len());
    let cv = F32x8::splat(c);
    let sv = F32x8::splat(s);
    let n8 = rp.len() - rp.len() % 8;
    let mut i = 0;
    while i < n8 {
        let p = F32x8::load(&rp[i..]);
        let q = F32x8::load(&rq[i..]);
        cv.mul(p).sub(sv.mul(q)).store(&mut rp[i..]);
        sv.mul(p).add(cv.mul(q)).store(&mut rq[i..]);
        i += 8;
    }
    for (p, q) in rp[n8..].iter_mut().zip(rq[n8..].iter_mut()) {
        let (wp, wq) = (*p, *q);
        *p = c * wp - s * wq;
        *q = s * wp + c * wq;
    }
}

/// Lane variant of the column-rotation phase: 8-row strips per pair, with
/// strided gathers into lanes. Pairs are disjoint within a round, so the
/// strip-outer / pair-inner order writes the same bits as the scalar
/// row-outer order; the per-element arithmetic is identical.
#[inline(always)]
fn rot_cols_block_lanes(
    rows: &mut [f32],
    n: usize,
    pairs: &[(usize, usize)],
    rot: &[Option<(f32, f32)>],
) {
    for strip in rows.chunks_mut(8 * n) {
        for (t, r) in rot.iter().enumerate() {
            if let Some((c, s)) = *r {
                let (p, q) = pairs[t];
                let mut lp = [0.0f32; 8];
                let mut lq = [0.0f32; 8];
                for (l, row) in strip.chunks(n).enumerate() {
                    lp[l] = row[p];
                    lq[l] = row[q];
                }
                let (pv, qv) = (F32x8(lp), F32x8(lq));
                let (cv, sv) = (F32x8::splat(c), F32x8::splat(s));
                let np = cv.mul(pv).sub(sv.mul(qv));
                let nq = sv.mul(pv).add(cv.mul(qv));
                for (l, row) in strip.chunks_mut(n).enumerate() {
                    row[p] = np.0[l];
                    row[q] = nq.0[l];
                }
            }
        }
    }
}

// ------------------------------------------------------- packed matmul ---

/// Pack the k-block rows [k0, k0 + kc) of row-major `b` (n columns) into
/// j-tile-major panels: panel tile `jt` holds `kc` consecutive 8-wide
/// stripes of columns [8*jt, 8*jt + 8), zero-padded past column n. The
/// microkernel then streams each tile with unit stride.
#[inline(always)]
fn pack_b_panel(panel: &mut [f32], b: &[f32], n: usize, k0: usize, kc: usize) {
    for (jt, tile) in panel.chunks_mut(kc * 8).enumerate() {
        let j0 = jt * 8;
        let w = 8.min(n - j0);
        for (kk, dst) in tile.chunks_mut(8).enumerate() {
            let at = (k0 + kk) * n + j0;
            dst[..w].copy_from_slice(&b[at..at + w]);
            dst[w..].fill(0.0);
        }
    }
}

/// crow += arow-block @ panel for one row of C, register-blocked two
/// j-tiles at a time (two independent accumulator chains hide the f32 add
/// latency without touching the per-element order: each C element still
/// accumulates in ascending-k order, and zero A elements are skipped
/// exactly like the scalar kernel).
#[inline(always)]
fn row_kernel(crow: &mut [f32], ak: &[f32], panel: &[f32], n: usize) {
    let kc = ak.len();
    let nt = n.div_ceil(8);
    let mut jt = 0;
    while jt + 2 <= nt {
        let t0 = &panel[jt * kc * 8..(jt + 1) * kc * 8];
        let t1 = &panel[(jt + 1) * kc * 8..(jt + 2) * kc * 8];
        let j0 = jt * 8;
        let w1 = 8.min(n - j0 - 8);
        let mut acc0 = F32x8::load(&crow[j0..]);
        let mut acc1 = F32x8::load_partial(&crow[j0 + 8..j0 + 8 + w1]);
        for (kk, &a) in ak.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let av = F32x8::splat(a);
            acc0 = acc0.add(av.mul(F32x8::load(&t0[kk * 8..])));
            acc1 = acc1.add(av.mul(F32x8::load(&t1[kk * 8..])));
        }
        acc0.store(&mut crow[j0..]);
        acc1.store_partial(&mut crow[j0 + 8..j0 + 8 + w1]);
        jt += 2;
    }
    if jt < nt {
        let j0 = jt * 8;
        let w = 8.min(n - j0);
        let tile = &panel[jt * kc * 8..(jt * kc + kc) * 8];
        let mut acc = F32x8::load_partial(&crow[j0..j0 + w]);
        for (bv, &a) in tile.chunks_exact(8).zip(ak) {
            if a == 0.0 {
                continue;
            }
            acc = acc.add(F32x8::splat(a).mul(F32x8::load(bv)));
        }
        acc.store_partial(&mut crow[j0..j0 + w]);
    }
}

#[inline(always)]
fn matmul_block_impl(crows: &mut [f32], arows: &[f32], b: &[f32], k: usize, n: usize) {
    let nt = n.div_ceil(8);
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        pool::with_scratch(nt * kc * 8, |panel| {
            pack_b_panel(panel, b, n, k0, kc);
            for (crow, arow) in crows.chunks_mut(n).zip(arows.chunks(k)) {
                row_kernel(crow, &arow[k0..k0 + kc], panel, n);
            }
        });
    }
}

// ---------------------------------------------------- AVX2 instantiation ---

/// Instantiate `_lanes` kernels under `#[target_feature(enable = "avx2")]`:
/// the inlined portable lane code compiles down to 256-bit vertical ops.
/// Same arithmetic in the same order — bitwise identical to the portable
/// instantiation, just faster.
#[cfg(target_arch = "x86_64")]
macro_rules! avx2_variants {
    ($(fn $avx2:ident => $lanes:ident ( $($p:ident : $t:ty),* ) $(-> $r:ty)?;)*) => {
        $(
            #[target_feature(enable = "avx2")]
            unsafe fn $avx2($($p: $t),*) $(-> $r)? {
                $lanes($($p),*)
            }
        )*
    };
}

#[cfg(target_arch = "x86_64")]
avx2_variants! {
    fn dot_avx2 => dot_lanes(x: &[f32], y: &[f32]) -> f32;
    fn sum_avx2 => sum_lanes(x: &[f32]) -> f32;
    fn sum_sq_avx2 => sum_sq_lanes(x: &[f32]) -> f32;
    fn sse_about_avx2 => sse_about_lanes(x: &[f32], mean: f32) -> f32;
    fn max_abs_avx2 => max_abs_lanes(x: &[f32]) -> f32;
    fn axpy_avx2 => axpy_lanes(dst: &mut [f32], a: f32, src: &[f32]);
    fn scale_into_avx2 => scale_into_lanes(out: &mut [f32], src: &[f32], s: f32);
    fn add_into_avx2 => add_into_lanes(out: &mut [f32], a: &[f32], b: &[f32]);
    fn sub_into_avx2 => sub_into_lanes(out: &mut [f32], a: &[f32], b: &[f32]);
    fn ema_avx2 => ema_lanes(dst: &mut [f32], a: f32, src: &[f32], b: f32);
    fn normalize_avx2 => normalize_lanes(dst: &mut [f32], mean: f32, std: f32);
    fn sq_accum_avx2 => sq_accum_lanes(acc: &mut [f32], row: &[f32]);
    fn rot2_avx2 => rot2_lanes(rp: &mut [f32], rq: &mut [f32], c: f32, s: f32);
    fn rot_cols_block_avx2 => rot_cols_block_lanes(
        rows: &mut [f32], n: usize, pairs: &[(usize, usize)], rot: &[Option<(f32, f32)>]);
    fn matmul_block_avx2 => matmul_block_impl(
        crows: &mut [f32], arows: &[f32], b: &[f32], k: usize, n: usize);
}

// ---------------------------------------------------------- dispatchers ---
// Pattern: scalar when the feature is off or `with_scalar` is in force;
// otherwise the AVX2 instantiation when the CPU has it, else portable
// lanes. The `active()` test is a compile-time constant `false` without
// the feature, so default builds pay nothing.

/// Dot product. Reduction — fixed lane tree, ulp-bounded vs scalar.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    if !active() {
        return scalar::dot(x, y);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { dot_avx2(x, y) };
        }
    }
    dot_lanes(x, y)
}

/// Plain sum. Reduction — fixed lane tree, ulp-bounded vs scalar.
pub fn sum(x: &[f32]) -> f32 {
    if !active() {
        return scalar::sum(x);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { sum_avx2(x) };
        }
    }
    sum_lanes(x)
}

/// Sum of squares. Reduction — fixed lane tree, ulp-bounded vs scalar.
pub fn sum_sq(x: &[f32]) -> f32 {
    if !active() {
        return scalar::sum_sq(x);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { sum_sq_avx2(x) };
        }
    }
    sum_sq_lanes(x)
}

/// Sum of squared deviations about `mean`. Reduction — ulp-bounded.
pub fn sse_about(x: &[f32], mean: f32) -> f32 {
    if !active() {
        return scalar::sse_about(x, mean);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { sse_about_avx2(x, mean) };
        }
    }
    sse_about_lanes(x, mean)
}

/// Max |x|. Regrouped, but max is order-insensitive: same result always.
pub fn max_abs(x: &[f32]) -> f32 {
    if !active() {
        return scalar::max_abs(x);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { max_abs_avx2(x) };
        }
    }
    max_abs_lanes(x)
}

/// dst += a * src. Vertical — bitwise identical to scalar.
pub fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
    if !active() {
        return scalar::axpy(dst, a, src);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { axpy_avx2(dst, a, src) };
        }
    }
    axpy_lanes(dst, a, src)
}

/// out = src * s. Vertical — bitwise identical to scalar.
pub fn scale_into(out: &mut [f32], src: &[f32], s: f32) {
    if !active() {
        return scalar::scale_into(out, src, s);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { scale_into_avx2(out, src, s) };
        }
    }
    scale_into_lanes(out, src, s)
}

/// out = a + b. Vertical — bitwise identical to scalar.
pub fn add_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    if !active() {
        return scalar::add_into(out, a, b);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { add_into_avx2(out, a, b) };
        }
    }
    add_into_lanes(out, a, b)
}

/// out = a - b. Vertical — bitwise identical to scalar.
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    if !active() {
        return scalar::sub_into(out, a, b);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { sub_into_avx2(out, a, b) };
        }
    }
    sub_into_lanes(out, a, b)
}

/// dst = a * dst + b * src. Vertical — bitwise identical to scalar.
pub fn ema(dst: &mut [f32], a: f32, src: &[f32], b: f32) {
    if !active() {
        return scalar::ema(dst, a, src, b);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { ema_avx2(dst, a, src, b) };
        }
    }
    ema_lanes(dst, a, src, b)
}

/// dst = (dst - mean) / std. Vertical — bitwise identical to scalar.
pub fn normalize(dst: &mut [f32], mean: f32, std: f32) {
    if !active() {
        return scalar::normalize(dst, mean, std);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { normalize_avx2(dst, mean, std) };
        }
    }
    normalize_lanes(dst, mean, std)
}

/// acc += row². Vertical — bitwise identical to scalar.
pub fn sq_accum(acc: &mut [f32], row: &[f32]) {
    if !active() {
        return scalar::sq_accum(acc, row);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { sq_accum_avx2(acc, row) };
        }
    }
    sq_accum_lanes(acc, row)
}

/// Jacobi row-pair rotation. Vertical — bitwise identical to scalar.
pub fn rot2(rp: &mut [f32], rq: &mut [f32], c: f32, s: f32) {
    if !active() {
        return scalar::rot2(rp, rq, c, s);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { rot2_avx2(rp, rq, c, s) };
        }
    }
    rot2_lanes(rp, rq, c, s)
}

/// Jacobi column-rotation phase over a row-major block. Disjoint pairs —
/// bitwise identical to scalar in any loop order.
pub fn rot_cols_block(
    rows: &mut [f32],
    n: usize,
    pairs: &[(usize, usize)],
    rot: &[Option<(f32, f32)>],
) {
    if !active() {
        return scalar::rot_cols_block(rows, n, pairs, rot);
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { rot_cols_block_avx2(rows, n, pairs, rot) };
        }
    }
    rot_cols_block_lanes(rows, n, pairs, rot)
}

/// One row-block of C += A-block @ B through the packed 8-wide
/// microkernel: `crows` are contiguous rows of C (n columns), `arows` the
/// matching rows of A (row-major, stride k), `b` the full row-major k x n
/// right factor. B panels are packed once per (row-block task, k-block)
/// into the pool's per-thread scratch, so the tiles compose with the
/// `util::pool` row-block fan-out instead of fighting it. Per-element
/// accumulation stays in ascending-k order with the scalar kernel's
/// zero-skip, independent of pool width and row-block partition.
///
/// Unlike the slice kernels above this does **not** consult [`active`] —
/// `Mat::matmul` selects between this and its scalar block kernel once
/// per call.
pub fn matmul_block_packed(crows: &mut [f32], arows: &[f32], b: &[f32], k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: AVX2 support verified at runtime just above.
            return unsafe { matmul_block_avx2(crows, arows, b, k, n) };
        }
    }
    matmul_block_impl(crows, arows, b, k, n)
}

/// C = A @ B, **overwriting** C (`c.len() / n` rows; `a` row-major
/// rows x k, `b` row-major k x n) — the tile-rotation product of the
/// blocked Jacobi path. One dispatch per call, like the slice kernels:
/// the scalar accumulation loop under [`with_scalar`] / without the
/// feature, the packed microkernel otherwise. Both paths accumulate each
/// C element in ascending-k order, so the result is deterministic and
/// independent of how the caller partitioned its rows (the blocked
/// Jacobi width contract rides on this); scalar↔simd drift is
/// ulp-bounded (`tests/simd_parity.rs`).
pub fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
    c.fill(0.0);
    if !active() {
        return scalar::matmul_acc(c, a, b, k, n);
    }
    matmul_block_packed(c, a, b, k, n)
}

// ------------------------------------------------------ strided copies ---

/// dst[i] = src[i * stride] — the strided column gather shared by
/// `Mat::col_vec`, `kron::vec_cols`, and the QR working-set loads.
pub fn gather_stride(dst: &mut [f32], src: &[f32], stride: usize) {
    for (d, s) in dst.iter_mut().zip(src.iter().step_by(stride)) {
        *d = *s;
    }
}

/// dst[i * stride] = src[i] — the matching scatter (`Mat::set_col`,
/// `kron::mat_cols`).
pub fn scatter_stride(dst: &mut [f32], stride: usize, src: &[f32]) {
    for (d, s) in dst.iter_mut().step_by(stride).zip(src) {
        *d = *s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    /// Ragged lengths straddling the 8- and 16-lane stripe edges.
    const LENS: &[usize] = &[0, 1, 7, 8, 9, 15, 16, 17, 40, 129];

    #[test]
    fn reductions_lane_vs_scalar_ulp_bounded() {
        let mut rng = Pcg::seeded(1);
        for &n in LENS {
            let x = rng.normal_vec(n, 1.0);
            let y = rng.normal_vec(n, 1.0);
            assert!(close(dot_lanes(&x, &y), scalar::dot(&x, &y), 1e-5), "dot n={n}");
            assert!(close(sum_lanes(&x), scalar::sum(&x), 1e-5), "sum n={n}");
            assert!(close(sum_sq_lanes(&x), scalar::sum_sq(&x), 1e-5), "sum_sq n={n}");
            assert!(
                close(sse_about_lanes(&x, 0.25), scalar::sse_about(&x, 0.25), 1e-5),
                "sse n={n}"
            );
            assert_eq!(
                max_abs_lanes(&x).to_bits(),
                scalar::max_abs(&x).to_bits(),
                "max_abs is order-insensitive, n={n}"
            );
        }
    }

    #[test]
    fn vertical_kernels_bitwise_equal_scalar() {
        let mut rng = Pcg::seeded(2);
        for &n in LENS {
            let src = rng.normal_vec(n, 1.0);
            let other = rng.normal_vec(n, 1.0);
            let base = rng.normal_vec(n, 1.0);

            let mut a = base.clone();
            let mut b = base.clone();
            axpy_lanes(&mut a, 0.37, &src);
            scalar::axpy(&mut b, 0.37, &src);
            assert_eq!(a, b, "axpy n={n}");

            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            scale_into_lanes(&mut a, &src, -1.25);
            scalar::scale_into(&mut b, &src, -1.25);
            assert_eq!(a, b, "scale n={n}");

            add_into_lanes(&mut a, &src, &other);
            scalar::add_into(&mut b, &src, &other);
            assert_eq!(a, b, "add n={n}");

            sub_into_lanes(&mut a, &src, &other);
            scalar::sub_into(&mut b, &src, &other);
            assert_eq!(a, b, "sub n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            ema_lanes(&mut a, 0.9, &src, 0.1);
            scalar::ema(&mut b, 0.9, &src, 0.1);
            assert_eq!(a, b, "ema n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            normalize_lanes(&mut a, 0.1, 1.7);
            scalar::normalize(&mut b, 0.1, 1.7);
            assert_eq!(a, b, "normalize n={n}");

            let mut a = base.clone();
            let mut b = base.clone();
            sq_accum_lanes(&mut a, &src);
            scalar::sq_accum(&mut b, &src);
            assert_eq!(a, b, "sq_accum n={n}");

            let mut ap = base.clone();
            let mut aq = src.clone();
            let mut bp = base.clone();
            let mut bq = src.clone();
            rot2_lanes(&mut ap, &mut aq, 0.8, 0.6);
            scalar::rot2(&mut bp, &mut bq, 0.8, 0.6);
            assert_eq!(ap, bp, "rot2 p n={n}");
            assert_eq!(aq, bq, "rot2 q n={n}");
        }
    }

    #[test]
    fn rot_cols_block_lane_vs_scalar_bitwise() {
        let mut rng = Pcg::seeded(3);
        // 13 rows x 11 cols: ragged strip (8 + 5 rows)
        let (rows, n) = (13usize, 11usize);
        let data = rng.normal_vec(rows * n, 1.0);
        let pairs = [(0usize, 4usize), (1, 9), (2, 7), (3, 10)];
        let rot = [
            Some((0.8f32, 0.6f32)),
            None,
            Some((0.6, -0.8)),
            Some((1.0, 0.0)),
        ];
        let mut a = data.clone();
        let mut b = data.clone();
        rot_cols_block_lanes(&mut a, n, &pairs, &rot);
        scalar::rot_cols_block(&mut b, n, &pairs, &rot);
        assert_eq!(a, b);
    }

    #[test]
    fn packed_matmul_matches_naive() {
        let mut rng = Pcg::seeded(4);
        // shapes straddling KC and the 8-wide tile edges, with a zero
        // sprinkled in to exercise the skip path
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 7, 9), (5, 64, 16), (4, 130, 23)] {
            let mut a = rng.normal_vec(m * k, 1.0);
            if !a.is_empty() {
                a[0] = 0.0;
            }
            let b = rng.normal_vec(k * n, 1.0);
            let mut c = vec![0.0f32; m * n];
            matmul_block_impl(&mut c, &a, &b, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += a[i * k + kk] * b[kk * n + j];
                    }
                    assert!(
                        close(c[i * n + j], acc, 1e-4),
                        "({m},{k},{n}) at ({i},{j}): {} vs {acc}",
                        c[i * n + j]
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_into_overwrites_and_matches_naive() {
        let mut rng = Pcg::seeded(6);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 9, 7), (13, 128, 40), (32, 96, 96)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            // garbage initial contents must not leak into the product
            let mut c = vec![f32::NAN; m * n];
            matmul_into(&mut c, &a, &b, k, n);
            let zero_a = vec![0.0f32; m * k];
            let mut c_scalar = vec![7.0f32; m * n];
            scalar::matmul_acc(&mut c_scalar, &zero_a, &b, k, n);
            assert_eq!(c_scalar, vec![7.0f32; m * n], "matmul_acc accumulates, never clears");
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += a[i * k + kk] * b[kk * n + j];
                    }
                    assert!(
                        close(c[i * n + j], acc, 1e-4),
                        "({m},{k},{n}) at ({i},{j}): {} vs {acc}",
                        c[i * n + j]
                    );
                }
            }
        }
    }

    #[test]
    fn strided_copies_roundtrip() {
        let src: Vec<f32> = (0..35).map(|i| i as f32).collect();
        // gather column 2 of a 5x7 row-major matrix
        let mut col = vec![0.0f32; 5];
        gather_stride(&mut col, &src[2..], 7);
        assert_eq!(col, vec![2.0, 9.0, 16.0, 23.0, 30.0]);
        // scatter it back into a zeroed buffer and check placement
        let mut dst = vec![0.0f32; 35];
        scatter_stride(&mut dst[2..], 7, &col);
        for (i, &v) in dst.iter().enumerate() {
            let expect = if i % 7 == 2 { i as f32 } else { 0.0 };
            assert_eq!(v, expect, "index {i}");
        }
    }

    #[test]
    fn with_scalar_forces_the_scalar_path() {
        assert_eq!(active(), cfg!(feature = "simd"));
        with_scalar(|| {
            assert!(!active());
            with_scalar(|| assert!(!active()));
            assert!(!active());
        });
        assert_eq!(active(), cfg!(feature = "simd"));
        // dispatchers must agree with the scalar kernels under forcing
        let mut rng = Pcg::seeded(5);
        let x = rng.normal_vec(40, 1.0);
        let y = rng.normal_vec(40, 1.0);
        let (d, s) = with_scalar(|| (dot(&x, &y), sum_sq(&x)));
        assert_eq!(d.to_bits(), scalar::dot(&x, &y).to_bits());
        assert_eq!(s.to_bits(), scalar::sum_sq(&x).to_bits());
    }

    #[test]
    fn hsum_uses_the_documented_lane_tree() {
        // lane values chosen so any other grouping changes the bits
        let v = F32x8([1.0e8, 1.0, -1.0e8, 1.0, 0.5, 0.25, 0.125, 0.0625]);
        let a = v.0;
        let want = ((a[0] + a[4]) + (a[1] + a[5])) + ((a[2] + a[6]) + (a[3] + a[7]));
        assert_eq!(v.hsum().to_bits(), want.to_bits());
    }

    #[test]
    fn load_partial_zero_fills() {
        let v = F32x8::load_partial(&[1.0, 2.0, 3.0]);
        assert_eq!(v.0, [1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let mut out = [9.0f32; 3];
        v.store_partial(&mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
    }
}
