//! Dense linear-algebra substrate (no BLAS/LAPACK offline): `Mat` plus the
//! decompositions the paper's optimizers need — MGS QR, Jacobi EVD,
//! subspace iteration (Alg. 10), Newton-Schulz roots (App. B.8) — and
//! Kronecker utilities for the `fisher` verification suite. Inner loops
//! live in [`simd`]: scalar by default, 8-lane microkernels (with runtime
//! AVX2 on x86_64) under the `simd` cargo feature.

pub mod decomp;
pub mod kron;
pub mod mat;
pub mod rangefinder;
pub mod simd;

pub use decomp::{
    complete_basis, inv_fourth_root, jacobi_eigh, jacobi_eigh_blocked,
    jacobi_eigh_rounds, jacobi_eigh_serial, mgs_qr, newton_schulz, ns_step,
    random_orthonormal, subspace_iter, whiten,
};
pub use kron::{block_diag, diag_m, diag_v, kron, mat_cols, vec_cols};
pub use mat::Mat;
pub use rangefinder::{sketched_eigh, sketched_eigh_mat, SketchSpec};
