//! Loopback ↔ TCP bitwise parity for the transport layer (in-process:
//! the coordinator and the workers share this test process, workers on
//! plain `std::thread`s talking to `127.0.0.1` sockets).
//!
//! The contract under test (`src/dist/transport.rs` module docs): the
//! tree reduce is defined over global microbatch indices, so a TCP run —
//! including mid-run joins and mid-round disconnect requeues — produces
//! exactly the loopback bits. `rust/tests/transport_e2e.rs` repeats the
//! same checks across real OS processes via the `dist-demo` subcommand.

use std::thread::{self, JoinHandle};

use alice_racs::bench;
use alice_racs::dist::transport::{dec_witness_frame, enc_witness, run_worker, WorkerReport};
use alice_racs::dist::{
    demo, run_round_via, DistConfig, RoundMode, TcpCoordinator, Transport, TransportKind,
    WireCfg, WitnessMember, WitnessReport, WorkerCfg,
};

fn wire(run_id: &str) -> WireCfg {
    WireCfg {
        run_id: run_id.to_string(),
        tick_ms: 1,
        join_timeout_s: 30.0,
        round_timeout_s: 60.0,
    }
}

fn spawn_worker(
    addr: String,
    run_id: &str,
    fail_after_micro: Option<usize>,
) -> JoinHandle<anyhow::Result<WorkerReport>> {
    let run_id = run_id.to_string();
    thread::spawn(move || {
        run_worker(
            &WorkerCfg { connect: addr, run_id, fail_after_micro, witness_path: None },
            &demo::demo_src(),
        )
    })
}

/// Full demo run over TCP: bind a coordinator, spawn one worker thread
/// per `fails` entry, drive, and join everything.
fn run_tcp_demo(
    cfg: &demo::DemoCfg,
    run_id: &str,
    fails: &[Option<usize>],
    min_workers: usize,
) -> (demo::DemoOut, Vec<WorkerReport>) {
    let mut tcp = TcpCoordinator::bind("127.0.0.1:0", wire(run_id)).expect("bind");
    let addr = tcp.local_addr().to_string();
    let handles: Vec<_> = fails
        .iter()
        .map(|&f| spawn_worker(addr.clone(), run_id, f))
        .collect();
    let dist_cfg = DistConfig {
        dp_workers: min_workers,
        min_workers,
        transport: TransportKind::Tcp,
        ..DistConfig::default()
    };
    let mut coord = dist_cfg.empty_coordinator();
    let out = demo::drive(&mut tcp, &mut coord, cfg).expect("tcp demo run");
    let reports = handles
        .into_iter()
        .map(|h| h.join().expect("worker thread").expect("worker run"))
        .collect();
    (out, reports)
}

#[test]
fn tcp_two_workers_match_loopback_bitwise() {
    let cfg = demo::DemoCfg { micro: 6, steps: 3, ..Default::default() };
    let reference = demo::run_loopback(&cfg, 2, 1).unwrap();
    let (out, reports) = run_tcp_demo(&cfg, "parity", &[None, None], 2);
    assert_eq!(out.loss_bits, reference.loss_bits, "per-step loss bits diverged");
    assert_eq!(out.weight_digest, reference.weight_digest, "weight bits diverged");
    assert_eq!(out.rounds, 3);
    assert_eq!(out.requeues, 0);
    // both workers actually executed shards, and nothing ran twice
    for r in &reports {
        assert!(r.shards > 0, "worker {} never got a shard", r.member);
    }
    let total: usize = reports.iter().map(|r| r.micro).sum();
    assert_eq!(total, 6 * 3, "every microbatch executed exactly once");
    // each worker saw one witness broadcast per round, and the ledger
    // agrees with the executed work
    for r in &reports {
        assert_eq!(r.witnesses.len(), 3, "worker {} missed a witness", r.member);
        assert!(r.witnesses.iter().all(|w| w.workers == 2 && w.requeues == 0));
        let ledger: u64 = r.witnesses.iter().map(|w| w.micro).sum();
        assert_eq!(ledger, 6 * 3, "witness ledger disagrees with executed microbatches");
    }
}

#[test]
fn mid_round_disconnect_requeues_bitwise() {
    // 2 workers, 6 microbatches/step: each executes 3 per round. A limit
    // of 4 lets the failing worker finish round 1 (3 micro), execute one
    // microbatch of round 2, then vanish without a ShardDone — the
    // coordinator must requeue its whole round-2 shard (3 indices) onto
    // the survivor, and the result must match an undisturbed loopback
    // run bit for bit.
    let cfg = demo::DemoCfg { micro: 6, steps: 2, ..Default::default() };
    let reference = demo::run_loopback(&cfg, 2, 1).unwrap();
    let (out, reports) = run_tcp_demo(&cfg, "chaos", &[None, Some(4)], 2);
    assert_eq!(out.loss_bits, reference.loss_bits, "requeue changed the loss bits");
    assert_eq!(out.weight_digest, reference.weight_digest, "requeue changed the weights");
    assert_eq!(out.requeues, 3, "the dead worker's round-2 shard requeues whole");
    let failed = reports.iter().find(|r| r.micro == 4).expect("failing worker report");
    assert_eq!(failed.shards, 1, "crashed mid-shard, so only round 1 counts");
    // the survivor's round-2 witness carries the requeue ledger the
    // coordinator saw, straight off the wire
    let survivor = reports.iter().find(|r| r.micro > 4).expect("survivor report");
    let last = survivor.witnesses.last().expect("survivor saw the final witness");
    assert_eq!(last.requeues, 3, "witness broadcast must carry the requeue count");
    assert!(
        last.members.iter().any(|m| !m.alive),
        "health ledger must mark the departed member: {last:?}"
    );
}

#[test]
fn tcp_pipelined_round_matches_loopback_phased_bitwise() {
    // the pipelined dataflow over the real wire, pinned against the
    // phased loopback reference: overlap (eager reduce at ShardDone
    // arrival + per-parameter fan-out) is scheduling only, so even
    // crossing transport AND round mode at once lands on the same bits
    let phased = demo::DemoCfg { micro: 6, steps: 3, ..Default::default() };
    let reference = demo::run_loopback(&phased, 2, 1).unwrap();
    let pipelined = demo::DemoCfg { round: RoundMode::Pipelined, ..phased };
    let (out, reports) = run_tcp_demo(&pipelined, "pipelined-parity", &[None, None], 2);
    assert_eq!(out.loss_bits, reference.loss_bits, "pipelined TCP loss bits diverged");
    assert_eq!(out.weight_digest, reference.weight_digest, "pipelined TCP weights diverged");
    assert_eq!(out.rounds, 3);
    assert_eq!(out.requeues, 0);
    let total: usize = reports.iter().map(|r| r.micro).sum();
    assert_eq!(total, 6 * 3, "every microbatch executed exactly once");
}

#[test]
fn tcp_pipelined_disconnect_requeues_bitwise() {
    // the chaos twin of the test above: the failing worker vanishes
    // mid-round-2 *after* some of its sibling spans may already sit in
    // the eager-reduce accumulator — the requeued re-execution must
    // cascade into the same maximal blocks the phased stack builds
    let phased = demo::DemoCfg { micro: 6, steps: 2, ..Default::default() };
    let reference = demo::run_loopback(&phased, 2, 1).unwrap();
    let pipelined = demo::DemoCfg { round: RoundMode::Pipelined, ..phased };
    let (out, reports) = run_tcp_demo(&pipelined, "chaos-pipelined", &[None, Some(4)], 2);
    assert_eq!(out.loss_bits, reference.loss_bits, "requeue changed the pipelined loss bits");
    assert_eq!(out.weight_digest, reference.weight_digest, "requeue changed the weights");
    assert_eq!(out.requeues, 3, "the dead worker's round-2 shard requeues whole");
    let failed = reports.iter().find(|r| r.micro == 4).expect("failing worker report");
    assert_eq!(failed.shards, 1, "crashed mid-shard, so only round 1 counts");
}

#[test]
fn witness_frame_roundtrips_the_wire_encoding() {
    // codec-level twin of the broadcast checks above: an arbitrary report
    // survives enc → frame → dec bit-for-bit (f64 fields are exact powers
    // of two on purpose — equality here is bitwise, not approximate)
    let w = WitnessReport {
        round: 9,
        workers: 2,
        micro: 12,
        requeues: 3,
        stragglers: 1,
        grad_secs: 0.125,
        reduce_secs: 0.0625,
        imbalance: 1.25,
        median_secs: 0.5,
        members: vec![
            WitnessMember { id: 1, alive: true, micro_done: 9, requeued: 3, straggles: 1 },
            WitnessMember { id: 2, alive: false, micro_done: 3, requeued: 0, straggles: 0 },
        ],
    };
    let frame = enc_witness(&w);
    assert_eq!(dec_witness_frame(&frame).expect("decode witness frame"), w);
}

#[test]
fn late_joiner_streams_latest_state() {
    let src = demo::demo_src();
    let mut tcp = TcpCoordinator::bind("127.0.0.1:0", wire("late")).expect("bind");
    let addr = tcp.local_addr().to_string();
    let a = spawn_worker(addr.clone(), "late", None);
    let dist_cfg = DistConfig {
        dp_workers: 1,
        min_workers: 1,
        transport: TransportKind::Tcp,
        ..DistConfig::default()
    };
    let mut coord = dist_cfg.empty_coordinator();
    // round 1 with worker A only, then publish a checkpoint
    let toks = demo::token_block(4, 1000);
    let r1 = run_round_via(&mut tcp, &mut coord, &src, &toks).expect("round 1");
    tcp.publish_state(1, &coord.snapshot(), b"ckpt-after-step-1").unwrap();
    // B connects only now — its Welcome must be followed by the cached
    // state. Keep running rounds (each pumps the event loop) until the
    // round machine has admitted it.
    let b = spawn_worker(addr, "late", None);
    let mut extra = 0;
    while coord.alive() < 2 && extra < 500 {
        extra += 1;
        let toks = demo::token_block(4, 1000 + extra);
        run_round_via(&mut tcp, &mut coord, &src, &toks).expect("extra round");
    }
    assert_eq!(coord.alive(), 2, "late joiner was never admitted");
    tcp.shutdown();
    let ra = a.join().unwrap().expect("worker A");
    let rb = b.join().unwrap().expect("worker B");
    let (step, snap, blob) = rb.joined_state.expect("late joiner must receive state");
    assert_eq!(step, 1);
    assert_eq!(blob, b"ckpt-after-step-1");
    assert!(!snap.is_empty(), "round snapshot rides along");
    // A saw the same broadcast live; and round 1 really ran on A alone
    assert_eq!(ra.joined_state.expect("broadcast to A").0, 1);
    assert!(ra.micro >= toks.len(), "A executed round 1");
    assert!(r1.loss.is_finite());
}

#[test]
fn wrong_run_id_is_rejected() {
    let mut tcp = TcpCoordinator::bind("127.0.0.1:0", wire("right-run")).expect("bind");
    let addr = tcp.local_addr().to_string();
    // the impostor connects first (its Hello is queued ahead of the real
    // worker's), so it is processed — and rejected — while the
    // coordinator waits for the real member
    let bad = spawn_worker(addr.clone(), "wrong-run", None);
    thread::sleep(std::time::Duration::from_millis(50));
    let good = spawn_worker(addr, "right-run", None);
    let dist_cfg = DistConfig {
        dp_workers: 1,
        min_workers: 1,
        transport: TransportKind::Tcp,
        ..DistConfig::default()
    };
    let mut coord = dist_cfg.empty_coordinator();
    let toks = demo::token_block(4, 7000);
    run_round_via(&mut tcp, &mut coord, &demo::demo_src(), &toks).expect("round");
    tcp.shutdown();
    let err = bad.join().unwrap().expect_err("mismatched run-id must not join");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("rejected") || msg.contains("expected Welcome"),
        "unexpected rejection error: {msg}"
    );
    good.join().unwrap().expect("matching run-id joins fine");
    assert_eq!(coord.alive(), 1, "only the matching worker became a member");
}

#[test]
fn env_selected_transport_matches_reference() {
    // the CI dist matrix runs this suite per AR_TRANSPORT={loopback,tcp}
    // × AR_ROUND={phased,pipelined} cell: every cell must land on the
    // same reference bits (phased loopback, the repo's ground truth)
    let reference =
        demo::run_loopback(&demo::DemoCfg { micro: 8, steps: 4, ..Default::default() }, 2, 1)
            .unwrap();
    let cfg = demo::DemoCfg {
        micro: 8,
        steps: 4,
        round: bench::bench_round(),
        ..Default::default()
    };
    let out = match bench::bench_transport() {
        TransportKind::Loopback => demo::run_loopback(&cfg, 3, 2).unwrap(),
        TransportKind::Tcp => run_tcp_demo(&cfg, "env-axis", &[None, None, None], 3).0,
    };
    assert_eq!(out.loss_bits, reference.loss_bits);
    assert_eq!(out.weight_digest, reference.weight_digest);
}
