//! End-to-end coordinator integration: training makes progress, runs are
//! reproducible, checkpoints resume exactly, fused and coordinator paths
//! land in the same neighborhood. Self-skips without `make artifacts`.

use alice_racs::config::{ExecPath, RunConfig};
use alice_racs::coordinator::{Checkpoint, Trainer};
use alice_racs::util::pool;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn base_cfg(opt: &str, tag: &str) -> RunConfig {
    let mut cfg = RunConfig::default().tuned_for(opt);
    cfg.artifacts = "artifacts".into();
    cfg.out_dir = format!(
        "{}/alice_racs_test_{tag}_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    cfg.steps = 12;
    cfg.eval_every = 0;
    cfg.log_every = 1000;
    cfg.hp.interval = 5;
    cfg.hp.rank = 16;
    cfg.hp.leading = 6;
    // CI's sketch matrix cell sets AR_REFRESH=sketch so this whole suite
    // (determinism, checkpoint resume, width parity) also runs against
    // the randomized-range-finder refresh path
    cfg.hp.refresh = alice_racs::bench::bench_refresh();
    cfg
}

#[test]
fn adam_training_reduces_loss() {
    if !have_artifacts() {
        return;
    }
    let cfg = base_cfg("adam", "adamloss");
    let mut tr = Trainer::new(cfg).unwrap();
    let first = tr.train_step(0.001).unwrap();
    let mut last = first;
    for _ in 1..25 {
        last = tr.train_step(0.001).unwrap();
    }
    assert!(
        last < first - 0.05,
        "loss should fall: first {first}, last {last}"
    );
}

#[test]
fn training_is_deterministic() {
    if !have_artifacts() {
        return;
    }
    let run = || {
        let cfg = base_cfg("racs", "det");
        let mut tr = Trainer::new(cfg).unwrap();
        let mut losses = Vec::new();
        for _ in 0..6 {
            losses.push(tr.train_step(0.01).unwrap());
        }
        losses
    };
    assert_eq!(run(), run(), "same seed must reproduce the loss sequence");
}

#[test]
fn checkpoint_resume_is_exact() {
    if !have_artifacts() {
        return;
    }
    // run A: 8 straight steps
    let mut a = Trainer::new(base_cfg("alice", "ckpt_a")).unwrap();
    for _ in 0..8 {
        a.train_step(0.01).unwrap();
    }
    // run B: 4 steps, checkpoint, restore into a FRESH trainer, verify
    // params match bit-for-bit right after restore, then that stepping
    // stays finite. (Full resume-vs-uninterrupted loss equivalence —
    // possible since the checkpoint carries the RNG/data-stream position —
    // is pinned down by `checkpoint_resume_replays_uninterrupted_run`.)
    let mut b1 = Trainer::new(base_cfg("alice", "ckpt_b")).unwrap();
    for _ in 0..4 {
        b1.train_step(0.01).unwrap();
    }
    let ck = b1.checkpoint();
    let path = format!(
        "{}/alice_racs_ck_{}.bin",
        std::env::temp_dir().display(),
        std::process::id()
    );
    ck.save(&path).unwrap();

    let mut b2 = Trainer::new(base_cfg("alice", "ckpt_c")).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    b2.restore(&loaded).unwrap();
    assert_eq!(b2.step, 4);
    for (p1, p2) in b1.params.iter().zip(&b2.params) {
        assert_eq!(
            p1.as_f32().unwrap(),
            p2.as_f32().unwrap(),
            "restored params must be bitwise identical"
        );
    }
    // continue training from the restored state
    for _ in 0..4 {
        let loss = b2.train_step(0.01).unwrap();
        assert!(loss.is_finite());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_resume_replays_uninterrupted_run() {
    if !have_artifacts() {
        return;
    }
    // The checkpoint carries the RNG/data-stream position, so a save →
    // restore → continue run must produce the *bitwise identical* loss
    // trajectory (and final params) of an uninterrupted run — at pool
    // width 1 (serial baseline) and width 4 alike. Each width is its own
    // closed world: losses are only compared within the same width.
    let half = 4;
    for width in [1usize, 4] {
        pool::with_threads(width, || {
            // uninterrupted: 2 * half steps straight through
            let mut a =
                Trainer::new(base_cfg("alice", &format!("resume_a_w{width}"))).unwrap();
            let mut losses_a = Vec::new();
            for _ in 0..2 * half {
                losses_a.push(a.train_step(0.01).unwrap());
            }
            // interrupted twin: half steps, checkpoint, fresh trainer,
            // restore, half more
            let mut b =
                Trainer::new(base_cfg("alice", &format!("resume_b_w{width}"))).unwrap();
            let mut losses_b = Vec::new();
            for _ in 0..half {
                losses_b.push(b.train_step(0.01).unwrap());
            }
            let path = format!(
                "{}/alice_racs_resume_w{width}_{}.bin",
                std::env::temp_dir().display(),
                std::process::id()
            );
            b.checkpoint().save(&path).unwrap();
            drop(b);
            let mut c =
                Trainer::new(base_cfg("alice", &format!("resume_c_w{width}"))).unwrap();
            c.restore(&Checkpoint::load(&path).unwrap()).unwrap();
            assert_eq!(c.step, half as u64);
            for _ in 0..half {
                losses_b.push(c.train_step(0.01).unwrap());
            }
            assert_eq!(
                losses_a, losses_b,
                "resumed metrics must be bitwise identical at width {width}"
            );
            for (pa, pc) in a.params.iter().zip(&c.params) {
                assert_eq!(
                    pa.as_f32().unwrap(),
                    pc.as_f32().unwrap(),
                    "resumed params must be bitwise identical at width {width}"
                );
            }
            let _ = std::fs::remove_file(&path);
        });
    }
}

#[test]
fn fused_and_coordinator_paths_agree_on_dynamics() {
    if !have_artifacts() {
        return;
    }
    // Same seed, same schedule: adam through the fused HLO step vs the
    // native coordinator path. Numerics differ slightly (f32 order of
    // operations), so compare the loss trajectory loosely.
    let steps = 8;
    let mut cfg_c = base_cfg("adam", "pc");
    cfg_c.steps = steps;
    let mut cfg_f = cfg_c.clone();
    cfg_f.out_dir += "_fused";
    cfg_f.path = ExecPath::Fused;

    let mut tc = Trainer::new(cfg_c).unwrap();
    let mut tf = Trainer::new(cfg_f).unwrap();
    let mut lc = Vec::new();
    let mut lf = Vec::new();
    for _ in 0..steps {
        lc.push(tc.train_step(0.001).unwrap());
        lf.push(tf.train_step(0.001).unwrap());
    }
    for (a, b) in lc.iter().zip(&lf) {
        assert!(
            (a - b).abs() < 0.05,
            "paths diverged: coordinator {lc:?} vs fused {lf:?}"
        );
    }
}

#[test]
fn grad_accumulation_reduces_step_noise() {
    if !have_artifacts() {
        return;
    }
    // with 4 microbatches the averaged gradient is closer to the corpus
    // mean ⇒ the first-step loss is the average of 4 batch losses
    let mut cfg = base_cfg("sgd", "accum");
    cfg.grad_accum = 4;
    let mut tr = Trainer::new(cfg).unwrap();
    let loss = tr.train_step(0.01).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
}

#[test]
fn profile_phase_set_is_width_invariant() {
    if !have_artifacts() {
        return;
    }
    // Worker-side per-layer profiles are merged into the trainer's profile
    // at region end (Profile::absorb), so the *set* of accounted phases
    // must not depend on the pool width — a width-4 run that silently
    // dropped a worker's phases would desynchronize the profile report.
    let phases_at = |width: usize| {
        pool::with_threads(width, || {
            let mut tr =
                Trainer::new(base_cfg("alice", &format!("phases_w{width}"))).unwrap();
            for _ in 0..6 {
                tr.train_step(0.01).unwrap();
            }
            let mut p = tr.profile.phases();
            p.sort_unstable();
            p
        })
    };
    let w1 = phases_at(1);
    let w4 = phases_at(4);
    assert_eq!(w1, w4, "phase sets diverged between widths");
    assert!(w1.contains(&"opt_step_layer"), "{w1:?}");
    assert!(w1.contains(&"opt_refresh_layer"), "{w1:?}");
}

#[test]
fn state_elems_tracks_optimizer_memory() {
    if !have_artifacts() {
        return;
    }
    let tr_adam = Trainer::new(base_cfg("adam", "mem_a")).unwrap();
    let tr_racs = Trainer::new(base_cfg("racs", "mem_r")).unwrap();
    // RACS matrix states are O(m+n); the Adam-routed lm-head (paper
    // protocol) dominates its footprint, so compare with that included:
    // still well under half of full Adam.
    assert!(tr_racs.state_elems() * 3 < tr_adam.state_elems(),
            "racs {} vs adam {}", tr_racs.state_elems(), tr_adam.state_elems());
}
