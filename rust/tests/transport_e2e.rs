//! End-to-end transport test across real OS processes: a coordinator
//! process and worker processes talking over localhost TCP, all through
//! the `dist-demo` CLI subcommand. The acceptance bar from the module
//! docs: a 2-worker TCP run is bitwise identical to the in-process
//! loopback run — including one mid-run join and one mid-round
//! disconnect.
//!
//! The in-thread variant of these checks lives in
//! `rust/tests/transport_parity.rs`; this file only adds the process
//! boundary (argv plumbing, stdout protocol, real sockets between
//! processes).

use std::io::{BufRead, BufReader};
use std::process::{Child, ChildStdout, Command, Stdio};

use alice_racs::dist::demo;

const BIN: &str = env!("CARGO_BIN_EXE_alice-racs");

/// Spawn a coordinator process and block until it prints its bound
/// address (`listening HOST:PORT`).
fn spawn_coordinator(args: &[&str]) -> (Child, BufReader<ChildStdout>, String) {
    let mut child = Command::new(BIN)
        .args(["dist-demo", "--role", "coordinator", "--listen", "127.0.0.1:0"])
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn coordinator");
    let mut rd = BufReader::new(child.stdout.take().expect("coordinator stdout"));
    let mut line = String::new();
    rd.read_line(&mut line).expect("read listening line");
    let addr = line
        .trim()
        .strip_prefix("listening ")
        .unwrap_or_else(|| panic!("expected `listening HOST:PORT`, got {line:?}"))
        .to_string();
    (child, rd, addr)
}

fn spawn_worker(addr: &str, run_id: &str, extra: &[&str]) -> Child {
    Command::new(BIN)
        .args(["dist-demo", "--role", "worker", "--connect", addr, "--run-id", run_id])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn worker")
}

/// Read the coordinator's remaining output and return its `demo ...`
/// summary line, asserting a clean exit.
fn finish_coordinator(mut child: Child, rd: BufReader<ChildStdout>) -> String {
    let mut demo_line = None;
    for line in rd.lines() {
        let line = line.expect("coordinator stdout line");
        if line.starts_with("demo ") {
            demo_line = Some(line);
        }
    }
    let status = child.wait().expect("coordinator wait");
    assert!(status.success(), "coordinator exited with {status}");
    demo_line.expect("coordinator printed no demo summary line")
}

fn field<'a>(line: &'a str, key: &str) -> &'a str {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")[..]))
        .unwrap_or_else(|| panic!("no {key}= field in {line:?}"))
}

/// The `demo digest=... losses=...` line a loopback run of this shape
/// would print (`cmd_dist_demo` formats from the same `DemoOut`).
fn loopback_reference(micro: usize, steps: u64) -> (String, String) {
    let out =
        demo::run_loopback(&demo::DemoCfg { micro, steps, ..Default::default() }, 2, 1).unwrap();
    let losses: Vec<String> = out.loss_bits.iter().map(|b| format!("{b:08x}")).collect();
    (format!("{:016x}", out.weight_digest), losses.join(","))
}

fn worker_output(w: Child) -> String {
    let out = w.wait_with_output().expect("worker wait");
    assert!(out.status.success(), "worker exited with {}", out.status);
    String::from_utf8(out.stdout).expect("worker stdout utf8")
}

#[test]
fn two_process_tcp_run_matches_loopback_bitwise() {
    let (child, rd, addr) = spawn_coordinator(&[
        "--run-id", "e2e", "--min-workers", "2", "--micro", "6", "--steps", "3",
        "--tick-ms", "1",
    ]);
    let wa = spawn_worker(&addr, "e2e", &[]);
    let wb = spawn_worker(&addr, "e2e", &[]);
    let line = finish_coordinator(child, rd);
    let (ref_digest, ref_losses) = loopback_reference(6, 3);
    assert_eq!(field(&line, "digest"), ref_digest, "weight bits diverged: {line}");
    assert_eq!(field(&line, "losses"), ref_losses, "loss bits diverged: {line}");
    assert_eq!(field(&line, "requeues"), "0");
    for w in [wa, wb] {
        let out = worker_output(w);
        assert!(out.starts_with("worker member="), "unexpected worker output {out:?}");
    }
}

#[test]
fn mid_round_disconnect_across_processes_is_bitwise_invisible() {
    // same shape as the in-thread chaos test: each worker owns 3 of the 6
    // microbatches per round; a --fail-after-micro 4 worker survives
    // round 1, drops its connection one microbatch into round 2, and the
    // coordinator requeues its 3-index shard onto the survivor
    let (child, rd, addr) = spawn_coordinator(&[
        "--run-id", "e2e-chaos", "--min-workers", "2", "--micro", "6", "--steps", "2",
        "--tick-ms", "1",
    ]);
    let wa = spawn_worker(&addr, "e2e-chaos", &[]);
    let wb = spawn_worker(&addr, "e2e-chaos", &["--fail-after-micro", "4"]);
    let line = finish_coordinator(child, rd);
    let (ref_digest, ref_losses) = loopback_reference(6, 2);
    assert_eq!(field(&line, "digest"), ref_digest, "requeue changed the bits: {line}");
    assert_eq!(field(&line, "losses"), ref_losses);
    assert_eq!(field(&line, "requeues"), "3");
    let _ = worker_output(wa);
    let _ = worker_output(wb); // the chaos worker exits cleanly too
}

#[test]
fn mid_run_join_across_processes_is_bitwise_invisible() {
    // slow the ticks down so a third worker, spawned mid-run, reliably
    // joins while rounds are still going; re-partitioning onto it must
    // not move a single bit, and it must receive the streamed state
    let (child, rd, addr) = spawn_coordinator(&[
        "--run-id", "e2e-join", "--min-workers", "2", "--micro", "6", "--steps", "16",
        "--tick-ms", "30",
    ]);
    let wa = spawn_worker(&addr, "e2e-join", &[]);
    let wb = spawn_worker(&addr, "e2e-join", &[]);
    std::thread::sleep(std::time::Duration::from_millis(300));
    let wc = spawn_worker(&addr, "e2e-join", &[]);
    let line = finish_coordinator(child, rd);
    let (ref_digest, ref_losses) = loopback_reference(6, 16);
    assert_eq!(field(&line, "digest"), ref_digest, "mid-run join changed the bits: {line}");
    assert_eq!(field(&line, "losses"), ref_losses);
    let _ = worker_output(wa);
    let _ = worker_output(wb);
    let joiner = worker_output(wc);
    let joined_step: i64 = field(&joiner, "joined_step").parse().expect("joined_step");
    assert!(
        joined_step >= 1,
        "late joiner should have caught a published checkpoint: {joiner:?}"
    );
}
