//! Scalar ↔ SIMD parity harness for the `linalg::simd` microkernel layer,
//! mirroring `decomp_parity.rs`. Three contracts are pinned, all of them
//! meaningful under BOTH feature settings (without `--features simd` the
//! dispatch path *is* the scalar path and every check holds trivially —
//! which is itself the regression guard for the feature gating):
//!
//! * **ulp-bounded drift**: the dispatch path vs `simd::with_scalar` on
//!   ragged shapes for the matmul family and the horizontal reductions
//!   (the lane kernels regroup sums into a fixed lane tree).
//! * **bitwise equality** for the vertical kernels (elementwise family,
//!   per-row/col norms, rotations): same per-element ops in the same
//!   order, so the lane path may not drift at all.
//! * **bitwise width-invariance of the SIMD path** at pool widths {1, 4}:
//!   partitioning and lane geometry are pure functions of shape, never of
//!   the worker count.

use alice_racs::linalg::{
    jacobi_eigh, jacobi_eigh_blocked, mat_cols, mgs_qr, simd, vec_cols, Mat,
};
use alice_racs::util::{pool, Pcg};

/// Relative closeness bound for kernels that regroup float sums.
const ULP_TOL: f32 = 1e-4;

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length drift");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= ULP_TOL * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: scalar {x} vs simd {y}"
        );
    }
}

/// (m, k, n) straddling the 64-wide cache blocks, the 8-wide lane tiles,
/// and the 16-wide reduction stripes, plus degenerate edges.
const MM_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (7, 13, 5),
    (8, 16, 8),
    (9, 17, 15),
    (63, 65, 64),
    (65, 64, 63),
    (70, 130, 90),
    (129, 67, 3),
    (1, 200, 257),
    (200, 1, 129),
];

#[test]
fn matmul_family_scalar_vs_dispatch_ulp_bounded() {
    for &(m, k, n) in MM_SHAPES {
        let mut rng = Pcg::seeded((m * 1000 + k * 10 + n) as u64);
        let a = Mat::from_vec(m, k, rng.normal_vec(m * k, 1.0));
        let b = Mat::from_vec(k, n, rng.normal_vec(k * n, 1.0));
        let a_tn = Mat::from_vec(k, m, rng.normal_vec(k * m, 1.0));
        let b_nt = Mat::from_vec(n, k, rng.normal_vec(n * k, 1.0));
        let x = rng.normal_vec(k, 1.0);
        let scalar = simd::with_scalar(|| {
            (a.matmul(&b), a_tn.matmul_tn(&b), a.matmul_nt(&b_nt), a.matvec(&x))
        });
        let fast = (a.matmul(&b), a_tn.matmul_tn(&b), a.matmul_nt(&b_nt), a.matvec(&x));
        let tag = format!("{m}x{k}x{n}");
        assert_close(&scalar.0.data, &fast.0.data, &format!("matmul {tag}"));
        assert_close(&scalar.1.data, &fast.1.data, &format!("matmul_tn {tag}"));
        assert_close(&scalar.2.data, &fast.2.data, &format!("matmul_nt {tag}"));
        assert_close(&scalar.3, &fast.3, &format!("matvec {tag}"));
    }
}

#[test]
fn elementwise_family_bitwise_equals_scalar() {
    // vertical kernels: the lane path must not drift by a single bit
    for &n in &[1usize, 7, 8, 9, 40, 129, 1000] {
        let mut rng = Pcg::seeded(7 + n as u64);
        let a = Mat::from_vec(1, n, rng.normal_vec(n, 1.0));
        let b = Mat::from_vec(1, n, rng.normal_vec(n, 1.0));
        let run = || {
            let mut e = a.clone();
            e.ema_(0.9, &b, 0.1);
            (a.scale(1.5), a.add(&b), a.sub(&b), e)
        };
        let scalar = simd::with_scalar(run);
        let fast = run();
        assert_eq!(scalar.0.data, fast.0.data, "scale n={n}");
        assert_eq!(scalar.1.data, fast.1.data, "add n={n}");
        assert_eq!(scalar.2.data, fast.2.data, "sub n={n}");
        assert_eq!(scalar.3.data, fast.3.data, "ema_ n={n}");
    }
}

#[test]
fn reduction_family_scalar_vs_dispatch() {
    for &(m, n) in &[(1usize, 1usize), (5, 9), (33, 65), (130, 70)] {
        let mut rng = Pcg::seeded(11 + (m * n) as u64);
        let a = Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0));
        let run = || (a.fro_norm_sq(), a.max_abs(), a.col_sq_norms(), a.row_sq_norms());
        let scalar = simd::with_scalar(run);
        let fast = run();
        let tag = format!("{m}x{n}");
        assert_close(&[scalar.0], &[fast.0], &format!("fro_norm_sq {tag}"));
        // max is order-insensitive: regrouping cannot change it
        assert_eq!(scalar.1.to_bits(), fast.1.to_bits(), "max_abs {tag}");
        // col_sq_norms accumulates vertically — bitwise; row_sq_norms is
        // a per-row horizontal sum — ulp-bounded
        assert_eq!(scalar.2, fast.2, "col_sq_norms {tag}");
        assert_close(&scalar.3, &fast.3, &format!("row_sq_norms {tag}"));
    }
}

#[test]
fn simd_path_bitwise_width_invariant() {
    // the determinism contract of the dispatch path itself: identical
    // bytes at widths 1 and 4, whatever the feature setting selected
    let mut rng = Pcg::seeded(0x51fd);
    let a = Mat::from_vec(129, 65, rng.normal_vec(129 * 65, 1.0));
    let b = Mat::from_vec(65, 131, rng.normal_vec(65 * 131, 1.0));
    let tall = Mat::from_vec(129, 70, rng.normal_vec(129 * 70, 1.0));
    let wide = Mat::from_vec(90, 65, rng.normal_vec(90 * 65, 1.0));
    let big = Mat::from_vec(600, 450, rng.normal_vec(600 * 450, 1.0));
    let run = || {
        let mut e = big.clone();
        e.ema_(0.9, &big, 0.1);
        (
            a.matmul(&b),
            a.matmul_tn(&tall),
            a.matmul_nt(&wide),
            e,
            big.row_sq_norms(),
            big.max_abs(),
        )
    };
    let base = pool::with_threads(1, run);
    let par = pool::with_threads(4, run);
    assert_eq!(base.0.data, par.0.data, "matmul");
    assert_eq!(base.1.data, par.1.data, "matmul_tn");
    assert_eq!(base.2.data, par.2.data, "matmul_nt");
    assert_eq!(base.3.data, par.3.data, "ema_");
    assert_eq!(base.4, par.4, "row_sq_norms");
    assert_eq!(base.5.to_bits(), par.5.to_bits(), "max_abs");
}

#[test]
fn decompositions_agree_across_dispatch_paths() {
    // QR and Jacobi are iterative — scalar vs simd trajectories may drift
    // beyond elementwise ulp bounds, so pin the *invariants* on both
    // paths plus bitwise width-invariance per path (the contract
    // `decomp_parity.rs` pins for whichever path the build selects).
    let mut rng = Pcg::seeded(0xdec);
    let g = Mat::from_vec(200, 90, rng.normal_vec(200 * 90, 1.0));
    let bsrc = Mat::from_vec(121, 121, rng.normal_vec(121 * 121, 1.0));
    let mut spd = bsrc.matmul_nt(&bsrc);
    for i in 0..121 {
        *spd.at_mut(i, i) += 0.5;
    }
    let ortho_err = |q: &Mat| q.matmul_tn(q).sub(&Mat::eye(q.cols)).max_abs();
    for forced_scalar in [false, true] {
        let run = || {
            if forced_scalar {
                simd::with_scalar(|| (mgs_qr(&g), jacobi_eigh(&spd, 30)))
            } else {
                (mgs_qr(&g), jacobi_eigh(&spd, 30))
            }
        };
        let (q, (v, lam)) = run();
        assert!(ortho_err(&q) < 1e-3, "Q ortho err (forced={forced_scalar})");
        assert!(ortho_err(&v) < 1e-3, "V ortho err (forced={forced_scalar})");
        // reconstruction: V diag(λ) Vᵀ ≈ A
        let mut vd = v.clone();
        for r in 0..v.rows {
            for c in 0..v.cols {
                *vd.at_mut(r, c) *= lam[c];
            }
        }
        let err = vd.matmul_nt(&v).sub(&spd).max_abs();
        assert!(err < 2e-3 * spd.max_abs(), "reconstruction (forced={forced_scalar}): {err}");
        // width invariance holds on each dispatch path independently
        let w1 = pool::with_threads(1, run);
        let w4 = pool::with_threads(4, run);
        assert_eq!(w1.0.data, w4.0.data, "QR width (forced={forced_scalar})");
        assert_eq!(w1.1 .0.data, w4.1 .0.data, "eigh V width (forced={forced_scalar})");
        assert_eq!(w1.1 .1, w4.1 .1, "eigh λ width (forced={forced_scalar})");
    }
}

#[test]
fn matmul_into_scalar_vs_dispatch_ulp_bounded() {
    // the blocked-Jacobi tile-rotation product: overwrite semantics on
    // both dispatch paths, ulp-bounded drift between them
    for &(rows, k, n) in &[(1usize, 1usize, 1usize), (9, 17, 5), (32, 128, 40), (13, 96, 130)] {
        let mut rng = Pcg::seeded((rows * 100 + k + n) as u64);
        let a = rng.normal_vec(rows * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let mut c_scalar = vec![f32::NAN; rows * n]; // garbage must be overwritten
        let mut c_fast = vec![f32::NAN; rows * n];
        simd::with_scalar(|| simd::matmul_into(&mut c_scalar, &a, &b, k, n));
        simd::matmul_into(&mut c_fast, &a, &b, k, n);
        assert_close(&c_scalar, &c_fast, &format!("matmul_into {rows}x{k}x{n}"));
    }
}

#[test]
fn blocked_eigh_agrees_across_dispatch_paths() {
    // the blocked two-sided Jacobi routes its tile gathers and rotation
    // products through matmul_into: pin the invariants on both kernel
    // dispatch paths, plus bitwise width-invariance per path
    let mut rng = Pcg::seeded(0xb10c);
    let n = 130; // two full 64-tiles + a 2-wide sliver
    let bsrc = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    let mut spd = bsrc.matmul_nt(&bsrc);
    for i in 0..n {
        *spd.at_mut(i, i) += 0.5;
    }
    let ortho_err = |q: &Mat| q.matmul_tn(q).sub(&Mat::eye(q.cols)).max_abs();
    for forced_scalar in [false, true] {
        let run = |sweeps: usize| {
            if forced_scalar {
                simd::with_scalar(|| jacobi_eigh_blocked(&spd, sweeps))
            } else {
                jacobi_eigh_blocked(&spd, sweeps)
            }
        };
        let (v, lam) = run(30);
        assert!(ortho_err(&v) < 1e-3, "V ortho err (forced={forced_scalar})");
        let mut vd = v.clone();
        for r in 0..n {
            for c in 0..n {
                *vd.at_mut(r, c) *= lam[c];
            }
        }
        let err = vd.matmul_nt(&v).sub(&spd).max_abs();
        assert!(err < 2e-3 * spd.max_abs(), "reconstruction (forced={forced_scalar}): {err}");
        // width invariance holds on each dispatch path independently
        // (parity needs the full schedule, not convergence — 6 sweeps)
        let w1 = pool::with_threads(1, || run(6));
        let w4 = pool::with_threads(4, || run(6));
        assert_eq!(w1.0.data, w4.0.data, "blocked V width (forced={forced_scalar})");
        assert_eq!(w1.1, w4.1, "blocked λ width (forced={forced_scalar})");
    }
}

#[test]
fn strided_helpers_round_trip_through_mat_and_kron() {
    let mut rng = Pcg::seeded(42);
    let m = Mat::from_vec(13, 9, rng.normal_vec(13 * 9, 1.0));
    // col_vec/set_col route through the shared gather/scatter helpers
    let mut copy = Mat::zeros(13, 9);
    for j in 0..9 {
        copy.set_col(j, &m.col_vec(j));
    }
    assert_eq!(copy.data, m.data);
    // kron's column-stacking uses the same helpers
    let v = vec_cols(&m);
    for (j, chunk) in v.chunks(13).enumerate() {
        assert_eq!(chunk, &m.col_vec(j)[..], "column {j}");
    }
    let back = mat_cols(&v, 13, 9);
    assert_eq!(back.data, m.data);
}
