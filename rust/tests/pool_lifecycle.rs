//! Lifecycle contract of the persistent worker pool: workers are spawned
//! lazily, parked between regions, and reused — never respawned per
//! region; nested regions submit through the same pool; a panicking task
//! aborts its region and re-raises on the submitting thread; and the
//! thread-local width override keeps working (including the width-1
//! inline guarantee the determinism contract builds on).

use std::panic::catch_unwind;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Mutex;

use alice_racs::util::pool;

/// Widths used anywhere in this file — the reuse test grows the pool past
/// all of them first so concurrent sibling tests can't change the count.
const MAX_WIDTH: usize = 8;

fn grow_to_max() -> usize {
    let w = pool::available().max(MAX_WIDTH);
    pool::with_threads(w, || pool::run(4 * w, |_| {}));
    pool::worker_count()
}

#[test]
fn workers_are_reused_across_regions() {
    let settled = grow_to_max();
    assert!(settled >= MAX_WIDTH - 1, "lazy spawn must size to the width");
    for _ in 0..50 {
        pool::with_threads(4, || {
            pool::run(64, |_| {});
            let _ = pool::map(16, |i| i * 3);
        });
    }
    assert_eq!(
        pool::worker_count(),
        settled,
        "regions must be served by parked workers, not fresh spawns"
    );
}

#[test]
fn warmup_prespawns_without_running_work() {
    pool::with_threads(MAX_WIDTH, pool::warmup);
    assert!(pool::worker_count() >= MAX_WIDTH - 1);
}

#[test]
fn nested_regions_submit_through_the_shared_pool() {
    grow_to_max();
    let before = pool::worker_count();
    // 6 outer tasks each opening an inner region of 8 tasks: all 48 inner
    // units must run exactly once, and the workers must see the caller's
    // effective width (no serial-degradation pinning, no oversubscription)
    let inner_hits: Vec<AtomicU32> = (0..48).map(|_| AtomicU32::new(0)).collect();
    let widths_seen: Vec<AtomicUsize> = (0..6).map(|_| AtomicUsize::new(0)).collect();
    pool::with_threads(4, || {
        pool::run(6, |i| {
            widths_seen[i].store(pool::threads(), Ordering::Relaxed);
            pool::run(8, |j| {
                inner_hits[i * 8 + j].fetch_add(1, Ordering::Relaxed);
            });
        });
    });
    assert!(inner_hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    assert!(
        widths_seen.iter().all(|w| w.load(Ordering::Relaxed) == 4),
        "workers must adopt the submitting thread's width"
    );
    assert_eq!(pool::worker_count(), before, "nesting must not grow the pool");
}

#[test]
fn panics_propagate_out_of_workers() {
    grow_to_max();
    let caught = catch_unwind(|| {
        pool::with_threads(4, || {
            pool::run(64, |i| {
                if i == 31 {
                    panic!("lifecycle-test panic");
                }
            });
        });
    });
    let payload = caught.expect_err("task panic must reach the submitter");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
        .unwrap_or("");
    assert!(msg.contains("lifecycle-test panic"), "payload lost: {msg:?}");
    // the pool survives the panic and keeps serving regions afterwards
    let out = pool::with_threads(4, || pool::map(40, |i| i + 1));
    assert_eq!(out, (1..=40).collect::<Vec<_>>());
}

#[test]
fn lowered_knob_is_a_hard_cap_for_nested_regions() {
    // the ROADMAP thread-budget bug: after a wide run leaves ≥ MAX_WIDTH
    // parked workers behind, a *lowered* knob must still be a hard
    // process-wide cap for the whole computation — concurrent nested
    // sibling regions used to recruit the spare parked workers and
    // overshoot it. The root-region budget threads the cap through TLS.
    grow_to_max();
    let active = AtomicUsize::new(0);
    let high = AtomicUsize::new(0);
    pool::with_threads(2, || {
        pool::run(6, |_| {
            pool::run(8, |_| {
                let a = active.fetch_add(1, Ordering::SeqCst) + 1;
                high.fetch_max(a, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(1));
                active.fetch_sub(1, Ordering::SeqCst);
            });
        });
    });
    let peak = high.load(Ordering::SeqCst);
    assert!(
        peak <= 2,
        "a width-2 computation must never occupy more than 2 threads, saw {peak}"
    );
}

#[test]
fn tls_width_override_is_honored() {
    grow_to_max();
    assert_eq!(pool::with_threads(3, pool::threads), 3);
    pool::with_threads(3, || {
        pool::with_threads(1, || assert_eq!(pool::threads(), 1));
        assert_eq!(pool::threads(), 3, "inner override must restore");
    });
    // width 1 runs every task inline, in order, on the calling thread —
    // the serial baseline the determinism contract is anchored to
    let caller = std::thread::current().id();
    let order = Mutex::new(Vec::new());
    pool::with_threads(1, || {
        pool::run(16, |i| {
            assert_eq!(std::thread::current().id(), caller);
            order.lock().unwrap().push(i);
        });
    });
    assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<_>>());
}
