//! Observability integration: the span tracer under the thread pool
//! (token nesting, concurrent emit) and the headline guarantee of the
//! whole instrumentation layer — **tracing on or off never changes
//! numerics**, pinned here as bitwise parity of a full loopback dist
//! run. The counter registry rides along: a clean loopback run must
//! leave the wire and requeue ledgers untouched.
//!
//! The tracer and the `obs` registry are process-global, so every test
//! in this binary serializes on one lock (the same pattern the tracer's
//! unit tests use).

use std::path::PathBuf;
use std::sync::Mutex;

use alice_racs::dist::demo;
use alice_racs::obs;
use alice_racs::util::json::Json;
use alice_racs::util::{pool, trace};

static LOCK: Mutex<()> = Mutex::new(());

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("alice_trace_obs_{}_{name}", std::process::id()));
    p
}

fn parse_trace(path: &PathBuf) -> Json {
    let txt = std::fs::read_to_string(path).expect("trace file readable");
    Json::parse(&txt).expect("trace output must be valid JSON")
}

#[test]
fn nested_pool_regions_attribute_worker_spans() {
    let _g = LOCK.lock().unwrap();
    let path = tmp("nesting.json");
    trace::init(&path);
    pool::with_threads(4, || {
        let _outer = trace::region("test", "outer_region");
        let outer_tok = trace::current_region();
        assert_ne!(outer_tok, 0, "region must stamp a token");
        {
            let _inner = trace::region("test", "inner_region");
            let inner_tok = trace::current_region();
            assert_ne!(inner_tok, 0);
            assert_ne!(inner_tok, outer_tok, "nested region gets a fresh token");
            // spans inside pool workers inherit the *innermost* region's
            // token via the propagated context word
            pool::run(8, |_i| {
                let _s = trace::span("test", "worker_task");
            });
        }
        assert_eq!(trace::current_region(), outer_tok, "outer token restored on drop");
    });
    let out = trace::finish().unwrap().expect("trace written");
    let j = parse_trace(&out);
    let evs = j.arr_of("traceEvents").unwrap();
    let ctxs_of = |n: &str| -> Vec<f64> {
        evs.iter()
            .filter(|e| e.str_of("name").ok() == Some(n))
            .map(|e| e.get("args").and_then(|a| a.f64_of("ctx").ok()).expect("args.ctx"))
            .collect()
    };
    let outer_ctx = ctxs_of("outer_region");
    let inner_ctx = ctxs_of("inner_region");
    assert_eq!(outer_ctx.len(), 1);
    assert_eq!(inner_ctx.len(), 1);
    assert_ne!(outer_ctx[0], inner_ctx[0]);
    let workers = ctxs_of("worker_task");
    assert_eq!(workers.len(), 8, "every pool task's span must land in the sink");
    for c in &workers {
        assert_eq!(*c, inner_ctx[0], "worker span must attribute to the inner region");
    }
    let _ = std::fs::remove_file(&out);
}

#[test]
fn concurrent_width4_emit_writes_valid_json() {
    let _g = LOCK.lock().unwrap();
    let path = tmp("concurrent.json");
    trace::init(&path);
    pool::with_threads(4, || {
        let _r = trace::region("test", "fanout");
        pool::run(64, |i| {
            let _s = trace::span("test", if i % 2 == 0 { "even" } else { "odd" });
            std::hint::black_box(i * i);
        });
    });
    let out = trace::finish().unwrap().expect("trace written");
    let j = parse_trace(&out);
    let evs = j.arr_of("traceEvents").unwrap();
    let n = evs
        .iter()
        .filter(|e| matches!(e.str_of("name").ok(), Some("even") | Some("odd")))
        .count();
    assert_eq!(n, 64, "64 concurrent worker spans, none lost or torn");
    let _ = std::fs::remove_file(&out);
}

#[test]
fn tracing_never_changes_dist_round_numerics() {
    let _g = LOCK.lock().unwrap();
    // spans only read the clock and append to buffers — a traced loopback
    // run must reproduce the untraced bits exactly, at pool width 4 where
    // scheduling pressure is real
    pool::with_threads(4, || {
        let cfg = demo::DemoCfg { micro: 6, steps: 3, ..Default::default() };
        let off = demo::run_loopback(&cfg, 2, 1).unwrap();
        let path = tmp("parity.json");
        trace::init(&path);
        let on = demo::run_loopback(&cfg, 2, 1).unwrap();
        let out = trace::finish().unwrap().expect("trace written");
        assert_eq!(on.loss_bits, off.loss_bits, "tracing changed the loss bits");
        assert_eq!(on.weight_digest, off.weight_digest, "tracing changed the weights");
        // and the traced run really recorded the round machinery
        let j = parse_trace(&out);
        let evs = j.arr_of("traceEvents").unwrap();
        assert!(
            evs.iter().any(|e| e.str_of("name").ok() == Some("dp_round")),
            "traced run must contain the dp_round region"
        );
        let _ = std::fs::remove_file(&out);
    });
}

#[test]
fn obs_counters_stay_clean_on_a_loopback_run() {
    let _g = LOCK.lock().unwrap();
    obs::reset_all();
    let cfg = demo::DemoCfg { micro: 4, steps: 2, ..Default::default() };
    demo::run_loopback(&cfg, 2, 1).unwrap();
    assert_eq!(obs::wire_totals(), (0, 0), "loopback moves no wire bytes");
    assert_eq!(obs::REQUEUES.get(), 0, "a clean run requeues nothing");
    // snapshot() surfaces non-zero entries only, and report() renders it
    obs::STATE_BYTES.set(1234);
    let snap = obs::snapshot();
    assert!(snap.iter().any(|(n, v)| n == "opt.state_bytes" && *v == 1234), "{snap:?}");
    assert!(obs::report().contains("opt.state_bytes"));
    obs::reset_all();
}
