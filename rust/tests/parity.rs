//! HLO ↔ native parity: the AOT `opt_update_<opt>_<m>x<n>` artifacts
//! (L2 optimizers through L1 Pallas kernels, executed by PJRT) must agree
//! with the native Rust optimizer implementations on identical gradient
//! streams. This is the strongest correctness bond across all three
//! layers: two fully independent implementations, one contract.

use alice_racs::linalg::Mat;
use alice_racs::opt::{build, Hyper, Slot};
use alice_racs::runtime::{Engine, HostTensor};
use alice_racs::util::Pcg;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

/// Drive both implementations over `steps` shared gradients; compare the
/// applied deltas. HLO state tensors round-trip through the executable.
fn check_parity(e: &mut Engine, opt_name: &str, rows: usize, cols: usize, steps: u64, tol: f32) {
    let art = format!("opt_update_{opt_name}_{rows}x{cols}");
    if !e.manifest.artifacts.contains_key(&art) {
        eprintln!("skipping {art}: not in bundle");
        return;
    }
    let spec = e.manifest.artifact(&art).unwrap().clone();
    e.prepare(&art).expect(&art);
    // hyperparams must match what aot.py baked in
    let hp = manifest_hyper(e);
    let opt = build(opt_name, &hp).unwrap();
    let mut slot = Slot::new(opt, rows, cols);

    let mut state: Vec<HostTensor> = spec.inputs[3..]
        .iter()
        .map(|ts| {
            // state init mirrors the python init (identity-prefix for u)
            let mut t = HostTensor::zeros(&ts.shape);
            if ts.name.ends_with(".u") || ts.name == "state.u" {
                let (m, r) = (ts.shape[0], ts.shape[1]);
                let d = t.as_f32_mut().unwrap();
                for i in 0..m.min(r) {
                    d[i * r + i] = 1.0;
                }
            }
            t
        })
        .collect();

    let mut rng = Pcg::seeded(99);
    let lr = 0.01f32;
    for t in 1..=steps {
        let gdata = rng.normal_vec(rows * cols, 0.5);
        let g = Mat::from_vec(rows, cols, gdata.clone());

        // HLO path
        let mut inputs = vec![
            HostTensor::f32(vec![rows, cols], gdata),
            HostTensor::scalar_f32(lr),
            HostTensor::scalar_f32(t as f32),
        ];
        inputs.extend(state.iter().cloned());
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        let outs = e.execute(&art, &refs).expect(&art);
        let hlo_delta = outs[0].as_f32().unwrap().to_vec();
        state = outs.into_iter().skip(1).collect();

        // native path (returns unscaled direction)
        let native = slot.step(&g, t);

        let mut max_err = 0.0f32;
        let mut max_mag = 0.0f32;
        for (h, n) in hlo_delta.iter().zip(&native.data) {
            max_err = max_err.max((h - lr * n).abs());
            max_mag = max_mag.max(h.abs());
        }
        assert!(
            max_err <= tol * max_mag.max(1e-3),
            "{art} t={t}: parity err {max_err} vs magnitude {max_mag}"
        );
    }
}

fn manifest_hyper(e: &Engine) -> Hyper {
    let h = &e.manifest.hyperparams;
    let get = |k: &str, d: f64| *h.get(k).unwrap_or(&d);
    Hyper {
        b1: get("b1", 0.9) as f32,
        b2: get("b2", 0.999) as f32,
        b3: get("b3", 0.999) as f32,
        eps: get("eps", 1e-8) as f32,
        rank: get("rank", 32.0) as usize,
        leading: get("leading", 10.0) as usize,
        interval: get("interval", 200.0) as usize,
        alpha: get("alpha", 1.0) as f32,
        alpha_c: get("alpha_c", 0.4) as f32,
        gamma: get("gamma", 1.01) as f32,
        beta_racs: get("beta_racs", 0.9) as f32,
        racs_iters: get("racs_iters", 5.0) as usize,
        ns_iters: get("ns_iters", 6.0) as usize,
        ..Hyper::default()
    }
}

#[test]
fn adam_parity_tall_and_wide() {
    let Some(mut e) = engine() else { return };
    check_parity(&mut e, "adam", 64, 176, 4, 2e-3);
    check_parity(&mut e, "adam", 176, 64, 4, 2e-3);
}

#[test]
fn racs_parity() {
    let Some(mut e) = engine() else { return };
    check_parity(&mut e, "racs", 64, 176, 4, 5e-3);
    check_parity(&mut e, "racs", 256, 64, 3, 5e-3);
}

#[test]
fn galore_parity_first_block() {
    // before any refresh both sides hold the identity-prefix projection,
    // so the GaLore update must agree exactly
    let Some(mut e) = engine() else { return };
    check_parity(&mut e, "galore", 64, 176, 3, 5e-3);
}

#[test]
fn alice_parity_first_block() {
    let Some(mut e) = engine() else { return };
    check_parity(&mut e, "alice", 64, 176, 3, 2e-2);
    check_parity(&mut e, "alice", 176, 64, 3, 2e-2);
}
