//! Integration: AOT artifacts → PJRT round trip.
//!
//! Requires `make artifacts` (nano preset). Tests self-skip when the
//! artifact directory is absent so `cargo test` stays green pre-AOT.

use alice_racs::runtime::{Engine, HostTensor};
use alice_racs::util::Pcg;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

fn init_params(e: &Engine, seed: u64) -> Vec<HostTensor> {
    let mut rng = Pcg::seeded(seed);
    e.manifest
        .params
        .iter()
        .map(|p| {
            let elems: usize = p.shape.iter().product();
            let data = if p.init_std == 0.0 {
                vec![1.0; elems]
            } else {
                rng.normal_vec(elems, p.init_std)
            };
            HostTensor::f32(p.shape.clone(), data)
        })
        .collect()
}

/// prepare + execute over owned inputs — the canonical entry point pair.
fn exec(e: &mut Engine, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
    e.prepare(name)?;
    let refs: Vec<&HostTensor> = inputs.iter().collect();
    e.execute(name, &refs)
}

fn tokens(e: &Engine, seed: u64) -> HostTensor {
    let m = &e.manifest.model;
    let mut rng = Pcg::seeded(seed);
    let data: Vec<i32> = (0..m.batch * m.seq)
        .map(|_| rng.below(m.vocab) as i32)
        .collect();
    HostTensor::i32(vec![m.batch, m.seq], data)
}

#[test]
fn grad_step_loss_near_uniform_and_grads_finite() {
    let Some(mut e) = engine() else { return };
    let params = init_params(&e, 1);
    let mut inputs = vec![tokens(&e, 2)];
    inputs.extend(params.iter().cloned());
    let outs = exec(&mut e, "grad_step", &inputs).expect("grad_step");
    let loss = outs[0].scalar().unwrap();
    let uniform = (e.manifest.model.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.3,
        "initial loss {loss} should be near ln(V) = {uniform}"
    );
    assert_eq!(outs.len(), 1 + params.len());
    for (o, p) in outs.iter().skip(1).zip(&e.manifest.params) {
        assert_eq!(o.shape(), p.shape.as_slice(), "{}", p.name);
        assert!(
            o.as_f32().unwrap().iter().all(|x| x.is_finite()),
            "{} grad not finite",
            p.name
        );
    }
}

#[test]
fn eval_loss_is_deterministic() {
    let Some(mut e) = engine() else { return };
    let params = init_params(&e, 3);
    let mut inputs = vec![tokens(&e, 4)];
    inputs.extend(params.iter().cloned());
    let a = exec(&mut e, "eval_loss", &inputs).unwrap()[0].scalar().unwrap();
    let b = exec(&mut e, "eval_loss", &inputs).unwrap()[0].scalar().unwrap();
    assert_eq!(a, b, "same inputs must produce bitwise-equal loss");
}

#[test]
fn grad_matches_finite_difference_on_final_norm() {
    // Directional finite-difference check of the AOT gradient: perturb the
    // final_norm gain (small tensor) and compare Δloss to ⟨g, Δw⟩.
    let Some(mut e) = engine() else { return };
    let params = init_params(&e, 5);
    let toks = tokens(&e, 6);
    let idx = e.manifest.param_index("final_norm").unwrap();

    let mut inputs = vec![toks.clone()];
    inputs.extend(params.iter().cloned());
    let outs = exec(&mut e, "grad_step", &inputs).unwrap();
    let loss0 = outs[0].scalar().unwrap();
    let g = outs[1 + idx].as_f32().unwrap().to_vec();

    let eps = 1e-3f32;
    let mut perturbed = params.clone();
    {
        let w = perturbed[idx].as_f32_mut().unwrap();
        for wi in w.iter_mut() {
            *wi += eps;
        }
    }
    let mut inputs2 = vec![toks];
    inputs2.extend(perturbed.iter().cloned());
    let loss1 = exec(&mut e, "eval_loss", &inputs2).unwrap()[0].scalar().unwrap();
    let predicted: f32 = g.iter().sum::<f32>() * eps;
    let actual = loss1 - loss0;
    assert!(
        (predicted - actual).abs() < 0.25 * predicted.abs().max(1e-3),
        "fd check: predicted {predicted}, actual {actual}"
    );
}

#[test]
fn manifest_shapes_are_enforced() {
    let Some(mut e) = engine() else { return };
    // wrong token shape must be rejected before reaching PJRT — driven
    // through the deprecated `run` forwarder, which keeps the compat
    // shims over `prepare` + `execute` covered
    let bad = HostTensor::i32(vec![1, 3], vec![0, 1, 2]);
    let mut inputs = vec![bad];
    inputs.extend(init_params(&e, 7));
    assert!(e.run("grad_step", &inputs).is_err());
    // wrong arity too
    assert!(e.run("grad_step", &[]).is_err());
}

#[test]
fn opt_update_artifacts_execute() {
    let Some(mut e) = engine() else { return };
    let names: Vec<String> = e
        .manifest
        .artifacts
        .values()
        .filter(|a| a.kind == "opt_update")
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty(), "no opt_update artifacts in bundle");
    for name in names {
        let spec = e.manifest.artifact(&name).unwrap().clone();
        let mut rng = Pcg::seeded(11);
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, ts)| {
                if i == 0 {
                    HostTensor::f32(ts.shape.clone(), rng.normal_vec(ts.elems(), 0.1))
                } else if ts.name == "lr" {
                    HostTensor::scalar_f32(0.01)
                } else if ts.name == "t" {
                    HostTensor::scalar_f32(1.0)
                } else {
                    HostTensor::zeros(&ts.shape)
                }
            })
            .collect();
        let outs = exec(&mut e, &name, &inputs).expect(&name);
        assert_eq!(outs.len(), spec.outputs.len(), "{name}");
        assert!(
            outs[0].as_f32().unwrap().iter().all(|x| x.is_finite()),
            "{name}: non-finite update"
        );
    }
}
