//! Serial ↔ parallel parity for the threaded execution backend: every
//! optimizer in the registry must produce the same updates at pool width 1
//! (the historical serial path) and width 4, over multiple steps including
//! a refresh; the `linalg` kernels must agree on ragged
//! (non-multiple-of-block) shapes. See `linalg::mat` for the determinism
//! contract these tests pin down.

use alice_racs::linalg::Mat;
use alice_racs::opt::{build, Hyper, Slot, ALL};
use alice_racs::testing::{Check, Gen};
use alice_racs::util::{pool, Pcg};

/// Drive one optimizer over `steps` shared gradients at the given pool
/// width; refreshes at t == 1 and every 3rd step afterwards.
fn drive(name: &str, hp: &Hyper, grads: &[Mat], width: usize) -> Vec<Mat> {
    pool::with_threads(width, || {
        let opt = build(name, hp).expect("registry");
        let (r, c) = (grads[0].rows, grads[0].cols);
        let mut slot = Slot::new(opt, r, c);
        grads
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let t = i as u64 + 1;
                if t == 1 || t % 3 == 0 {
                    slot.refresh(g, 0xbeef ^ t);
                }
                slot.step(g, t)
            })
            .collect()
    })
}

#[test]
fn every_optimizer_is_width_invariant() {
    let hp = Hyper { rank: 8, leading: 3, interval: 3, ..Hyper::default() };
    Check::new("optimizer-width-parity").runs(4).check(
        |g: &mut Gen| {
            // ragged, both orientations (covers transpose_wide)
            let r = g.dim(5, 70);
            let c = g.dim(5, 70);
            let steps = 5;
            (0..steps)
                .map(|_| Mat::from_vec(r, c, g.normal_vec(r * c, 0.1)))
                .collect::<Vec<Mat>>()
        },
        |grads| {
            for name in ALL {
                let serial = drive(name, &hp, grads, 1);
                let par = drive(name, &hp, grads, 4);
                for (t, (s, p)) in serial.iter().zip(&par).enumerate() {
                    let diff = s.sub(p).fro_norm();
                    if diff > 1e-6 {
                        return Err(format!(
                            "{name} {}x{} step {}: frobenius diff {diff}",
                            s.rows,
                            s.cols,
                            t + 1
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn matmul_family_parity_on_ragged_shapes() {
    // shapes straddling the 64-block edges: 1, block-1, block, block+1,
    // and decidedly non-multiple sizes
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (7, 13, 5),
        (63, 65, 64),
        (65, 64, 63),
        (70, 130, 90),
        (129, 67, 3),
        (1, 200, 257),
        (200, 1, 129),
    ];
    for &(m, k, n) in shapes {
        let mut rng = Pcg::seeded((m * 1000 + k * 10 + n) as u64);
        let a = Mat::from_vec(m, k, rng.normal_vec(m * k, 1.0));
        let b = Mat::from_vec(k, n, rng.normal_vec(k * n, 1.0));
        let a_tn = Mat::from_vec(k, m, rng.normal_vec(k * m, 1.0)); // k x m: a_tnᵀ @ b
        let b_nt = Mat::from_vec(n, k, rng.normal_vec(n * k, 1.0)); // a @ b_ntᵀ
        let serial = pool::with_threads(1, || {
            (a.matmul(&b), a_tn.matmul_tn(&b), a.matmul_nt(&b_nt), a.transpose())
        });
        for width in [2, 4, 7] {
            let par = pool::with_threads(width, || {
                (a.matmul(&b), a_tn.matmul_tn(&b), a.matmul_nt(&b_nt), a.transpose())
            });
            assert_eq!(serial.0.data, par.0.data, "matmul {m}x{k}x{n} width {width}");
            assert_eq!(serial.1.data, par.1.data, "matmul_tn {m}x{k}x{n} width {width}");
            assert_eq!(serial.2.data, par.2.data, "matmul_nt {m}x{k}x{n} width {width}");
            assert_eq!(serial.3.data, par.3.data, "transpose {m}x{k} width {width}");
        }
    }
}

#[test]
fn elementwise_and_reductions_parity_large() {
    // large enough to cross the parallel dispatch threshold (2^18 elements)
    let (m, n) = (531, 517);
    let mut rng = Pcg::seeded(0xcafe);
    let a = Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0));
    let b = Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0));
    let run_all = || {
        let mut e = a.clone();
        e.ema_(0.9, &b, 0.1);
        (
            a.scale(1.5),
            a.add(&b),
            a.sub(&b),
            e,
            a.fro_norm(),
            a.max_abs(),
            a.col_sq_norms(),
            a.row_sq_norms(),
        )
    };
    let serial = pool::with_threads(1, &run_all);
    let par = pool::with_threads(4, &run_all);
    // elementwise: bitwise
    assert_eq!(serial.0.data, par.0.data, "scale");
    assert_eq!(serial.1.data, par.1.data, "add");
    assert_eq!(serial.2.data, par.2.data, "sub");
    assert_eq!(serial.3.data, par.3.data, "ema_");
    // reductions: chunked combine, so float-tolerance
    assert!(
        (serial.4 - par.4).abs() <= 1e-4 * (1.0 + serial.4),
        "fro_norm {} vs {}",
        serial.4,
        par.4
    );
    assert_eq!(serial.5, par.5, "max_abs");
    for (s, p) in serial.6.iter().zip(&par.6) {
        assert!((s - p).abs() <= 1e-3 * (1.0 + s.abs()), "col_sq_norms {s} vs {p}");
    }
    assert_eq!(serial.7, par.7, "row_sq_norms");
}

#[test]
fn parallel_is_deterministic_at_fixed_width() {
    // same width twice → identical bytes, even while the pool fans out
    let hp = Hyper { rank: 8, leading: 3, interval: 3, ..Hyper::default() };
    let mut rng = Pcg::seeded(0xd00d);
    let grads: Vec<Mat> =
        (0..4).map(|_| Mat::from_vec(48, 66, rng.normal_vec(48 * 66, 0.1))).collect();
    for name in ["alice", "muon", "shampoo", "soap"] {
        let one = drive(name, &hp, &grads, 4);
        let two = drive(name, &hp, &grads, 4);
        for (a, b) in one.iter().zip(&two) {
            assert_eq!(a.data, b.data, "{name} not deterministic at width 4");
        }
    }
}
