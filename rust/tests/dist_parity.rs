//! Bitwise contract of the distributed subsystem: the round coordinator +
//! tree all-reduce must produce identical losses and identical post-step
//! weights for every `dp_workers` count and every pool width — including
//! ragged microbatch counts and mid-round straggler requeues. The
//! synthetic gradient source keeps these tests artifact-free (the PJRT
//! engine is exercised by the self-skipping trainer test at the end).

use alice_racs::bench::dp_sweep;
use alice_racs::dist::{
    reduce, run_round, run_round_pipelined, worker, DistConfig, EagerRound, Phase,
    RoundCoordinator, SyntheticGradSource,
};
use alice_racs::linalg::Mat;
use alice_racs::opt::{build, Hyper, Slot};
use alice_racs::runtime::HostTensor;
use alice_racs::util::pool;

fn tokens(micro: usize, seed: i32) -> Vec<HostTensor> {
    (0..micro)
        .map(|i| {
            let base = seed + i as i32 * 31;
            HostTensor::i32(vec![8], (0..8).map(|k| (base + k * 7) % 997).collect())
        })
        .collect()
}

fn src() -> SyntheticGradSource {
    SyntheticGradSource { shapes: vec![(6, 10), (8, 4), (1, 12)], work: 0 }
}

/// Run `steps` optimizer steps of a miniature training loop — synthetic
/// microbatch gradients through the full round pipeline, reduced grads
/// applied through real optimizer slots — and return (per-step losses,
/// final weights).
fn drive(dp: usize, width: usize, micro: usize, steps: u64) -> (Vec<u32>, Vec<Vec<f32>>) {
    pool::with_threads(width, || {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };
        let mut coord = dist.coordinator();
        let s = src();
        let hp = Hyper::default();
        let mut slots: Vec<Slot> = s
            .shapes
            .iter()
            .map(|&(r, c)| Slot::new(build("adam", &hp).expect("registry"), r, c))
            .collect();
        let mut weights: Vec<Mat> = s.shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
        let mut losses = Vec::new();
        for t in 1..=steps {
            let toks = tokens(micro, 1000 * t as i32);
            let out = run_round(&mut coord, &s, &toks).expect("round");
            losses.push(out.loss.to_bits());
            for ((slot, w), g) in slots.iter_mut().zip(&mut weights).zip(&out.grads) {
                if t == 1 {
                    slot.refresh(g, 0xd157 ^ t);
                }
                let delta = slot.step(g, t);
                w.ema_(1.0, &delta, -0.01);
            }
        }
        (losses, weights.into_iter().map(|w| w.data).collect())
    })
}

/// The pipelined twin of [`drive`]: same coordinator, slots, weights and
/// seeds, but each round runs through the eager-reduce path
/// ([`run_round_pipelined`]) and the optimizer applies per-parameter
/// folds ([`EagerRound::fold_param`]) instead of the monolithic reduced
/// gradients. Overlap is scheduling only, so the bits must match
/// [`drive`] exactly.
fn drive_pipelined(
    dp: usize,
    width: usize,
    micro: usize,
    steps: u64,
) -> (Vec<u32>, Vec<Vec<f32>>) {
    pool::with_threads(width, || {
        let dist = DistConfig { dp_workers: dp, ..DistConfig::default() };
        let mut coord = dist.coordinator();
        let s = src();
        let hp = Hyper::default();
        let mut slots: Vec<Slot> = s
            .shapes
            .iter()
            .map(|&(r, c)| Slot::new(build("adam", &hp).expect("registry"), r, c))
            .collect();
        let mut weights: Vec<Mat> = s.shapes.iter().map(|&(r, c)| Mat::zeros(r, c)).collect();
        let mut losses = Vec::new();
        for t in 1..=steps {
            let toks = tokens(micro, 1000 * t as i32);
            let round = run_round_pipelined(&mut coord, &s, &toks).expect("pipelined round");
            losses.push(round.fold_loss().to_bits());
            for (p, (slot, w)) in slots.iter_mut().zip(weights.iter_mut()).enumerate() {
                let g = round.fold_param(p);
                if t == 1 {
                    slot.refresh(&g, 0xd157 ^ t);
                }
                let delta = slot.step(&g, t);
                w.ema_(1.0, &delta, -0.01);
            }
        }
        (losses, weights.into_iter().map(|w| w.data).collect())
    })
}

#[test]
fn losses_and_weights_bitwise_equal_across_dp_and_width() {
    let steps = 4;
    for micro in [8usize, 5] {
        let reference = drive(1, 1, micro, steps);
        for dp in dp_sweep() {
            for width in [1usize, 4] {
                let got = drive(dp, width, micro, steps);
                assert_eq!(
                    got.0, reference.0,
                    "loss bits diverged: micro={micro} dp={dp} width={width}"
                );
                assert_eq!(
                    got.1, reference.1,
                    "weights diverged: micro={micro} dp={dp} width={width}"
                );
            }
        }
    }
}

#[test]
fn non_dividing_worker_counts_are_bitwise_equal_too() {
    let reference = drive(1, 1, 7, 3);
    for dp in [3usize, 5, 7] {
        let got = drive(dp, 4, 7, 3);
        assert_eq!(got.0, reference.0, "loss bits diverged at dp={dp}");
        assert_eq!(got.1, reference.1, "weights diverged at dp={dp}");
    }
}

#[test]
fn straggler_requeue_mid_round_keeps_the_reduced_bits() {
    // reference: a clean 3-worker round
    let s = src();
    let toks = tokens(9, 7);
    let dist = DistConfig { dp_workers: 3, ..DistConfig::default() };
    let mut clean = dist.coordinator();
    let reference = run_round(&mut clean, &s, &toks).expect("clean round");

    // faulty twin: worker 1 executes nothing and leaves mid-round; its
    // shard is requeued onto worker 2, which is still running
    let mut coord = dist.coordinator();
    coord.advance_to_train().unwrap();
    coord.begin_round(9).unwrap();
    assert_eq!(
        coord.assignments(),
        &[vec![0, 1, 2], vec![3, 4, 5], vec![6, 7, 8]]
    );
    let shard0 = worker::run_shard(&s, &coord.assignments()[0], &toks).unwrap();
    coord.complete(0, shard0.secs);
    coord.leave(1);
    let merged = coord.assignments()[2].clone();
    assert_eq!(merged, vec![6, 7, 8, 3, 4, 5], "requeue appends in index order");
    let shard2 = worker::run_shard(&s, &merged, &toks).unwrap();
    coord.complete(2, shard2.secs);
    assert_eq!(coord.tick(), Phase::Reduce);
    let mut nodes = shard0.nodes;
    nodes.extend(shard2.nodes);
    let root = reduce::combine(nodes).expect("non-empty");
    coord.finish_reduce(0.0);
    coord.tick();

    let scale = 1.0 / 9.0f32;
    assert_eq!(
        (root.loss * scale).to_bits(),
        reference.loss.to_bits(),
        "requeued round must reduce to the same loss bits"
    );
    for (g, r) in root.grads.iter().zip(&reference.grads) {
        assert_eq!(g.scale(scale).data, r.data, "requeued grads must match bitwise");
    }
    assert_eq!(coord.log[0].requeues, 3);
}

#[test]
fn resume_mid_round_from_snapshot_finishes_identically() {
    let s = src();
    let toks = tokens(6, 42);
    let dist = DistConfig { dp_workers: 2, ..DistConfig::default() };

    // uninterrupted round
    let mut a = dist.coordinator();
    let reference = run_round(&mut a, &s, &toks).expect("round");

    // interrupted twin: worker 0 finishes, then the coordinator is
    // snapshotted (checkpoint) and rebuilt before worker 1 runs
    let mut b = dist.coordinator();
    b.advance_to_train().unwrap();
    b.begin_round(6).unwrap();
    let shard0 = worker::run_shard(&s, &b.assignments()[0], &toks).unwrap();
    b.complete(0, shard0.secs);
    let snap = b.snapshot();
    drop(b);

    let mut c = RoundCoordinator::restore(dist.round_cfg(), &snap).unwrap();
    assert_eq!(c.phase, Phase::RoundTrain);
    assert_eq!(c.round, 1);
    // worker 0's in-flight nodes are recomputed from its (restored)
    // assignment — execution is pure, so the bits cannot change
    let redone0 = worker::run_shard(&s, &c.assignments()[0], &toks).unwrap();
    let shard1 = worker::run_shard(&s, &c.assignments()[1], &toks).unwrap();
    c.complete(1, shard1.secs);
    assert_eq!(c.tick(), Phase::Reduce);
    let mut nodes = redone0.nodes;
    nodes.extend(shard1.nodes);
    let root = reduce::combine(nodes).expect("non-empty");
    c.finish_reduce(0.0);
    c.tick();
    assert_eq!(c.round, 1);

    let scale = 1.0 / 6.0f32;
    assert_eq!((root.loss * scale).to_bits(), reference.loss.to_bits());
    for (g, r) in root.grads.iter().zip(&reference.grads) {
        assert_eq!(g.scale(scale).data, r.data);
    }
}

#[test]
fn run_round_drives_a_restored_mid_round_coordinator_to_the_same_bits() {
    // the trainer-realistic resume path: run_round itself consumes the
    // mid-round snapshot (re-arming via resume_round) — no hand-driving
    let s = src();
    let toks = tokens(6, 42);
    let dist = DistConfig { dp_workers: 2, ..DistConfig::default() };

    let mut a = dist.coordinator();
    let reference = run_round(&mut a, &s, &toks).expect("round");

    let mut b = dist.coordinator();
    b.advance_to_train().unwrap();
    b.begin_round(6).unwrap();
    let shard0 = worker::run_shard(&s, &b.assignments()[0], &toks).unwrap();
    b.complete(0, shard0.secs);
    let snap = b.snapshot();
    drop(b);

    let mut c = RoundCoordinator::restore(dist.round_cfg(), &snap).unwrap();
    let resumed = run_round(&mut c, &s, &toks).expect("resumed round");
    assert_eq!(resumed.loss.to_bits(), reference.loss.to_bits());
    for (g, r) in resumed.grads.iter().zip(&reference.grads) {
        assert_eq!(g.data, r.data);
    }
    assert_eq!(c.round, 1);
    assert_eq!(c.log.len(), 1);
    // the re-executed round credits member 0 exactly once
    assert_eq!(c.members[0].rounds_done, 1);
    assert_eq!(c.members[0].micro_done, 3);
}

// ------------------------------------------------ pipelined round parity ---

#[test]
fn pipelined_round_matches_phased_bitwise_across_dp_width_and_micro() {
    let steps = 3;
    for micro in [8usize, 5, 13] {
        let reference = drive(1, 1, micro, steps);
        for dp in dp_sweep() {
            for width in [1usize, 4] {
                let got = drive_pipelined(dp, width, micro, steps);
                assert_eq!(
                    got.0, reference.0,
                    "pipelined loss bits diverged: micro={micro} dp={dp} width={width}"
                );
                assert_eq!(
                    got.1, reference.1,
                    "pipelined weights diverged: micro={micro} dp={dp} width={width}"
                );
            }
        }
    }
}

#[test]
fn pipelined_requeue_mid_round_keeps_the_reduced_bits() {
    // reference: a clean phased 3-worker round
    let s = src();
    let toks = tokens(9, 7);
    let dist = DistConfig { dp_workers: 3, ..DistConfig::default() };
    let mut clean = dist.coordinator();
    let reference = run_round(&mut clean, &s, &toks).expect("clean round");

    // faulty twin, driven through the eager reduce: worker 0's nodes are
    // merged the moment they land, then worker 1 leaves mid-round and its
    // shard is requeued onto worker 2 — the late sibling cascades into
    // the already-merged spans
    let mut coord = dist.coordinator();
    coord.advance_to_train().unwrap();
    coord.begin_round(9).unwrap();
    let mut er = reduce::EagerReduce::new();
    let shard0 = worker::run_shard(&s, &coord.assignments()[0], &toks).unwrap();
    coord.complete(0, shard0.secs);
    let spans0: Vec<(usize, usize)> = shard0.nodes.iter().map(|n| (n.lo, n.len)).collect();
    coord.deliver_segments(&spans0);
    er.offer_all(shard0.nodes);
    coord.leave(1);
    let merged = coord.assignments()[2].clone();
    assert_eq!(merged, vec![6, 7, 8, 3, 4, 5], "requeue appends in index order");
    let shard2 = worker::run_shard(&s, &merged, &toks).unwrap();
    coord.complete(2, shard2.secs);
    let spans2: Vec<(usize, usize)> = shard2.nodes.iter().map(|n| (n.lo, n.len)).collect();
    coord.deliver_segments(&spans2);
    er.offer_all(shard2.nodes);
    assert_eq!(coord.tick(), Phase::Reduce);
    assert!(coord.segments_complete());
    assert_eq!(er.covered(), 9);
    coord.finish_reduce(0.0);
    coord.tick();

    let round = EagerRound {
        blocks: er.finish(),
        micro: 9,
        grad_secs: 0.0,
        reduce_secs: 0.0,
        reduce_overlap_secs: 0.0,
    };
    assert_eq!(
        round.fold_loss().to_bits(),
        reference.loss.to_bits(),
        "requeued eager round must fold to the same loss bits"
    );
    for (p, r) in reference.grads.iter().enumerate() {
        assert_eq!(
            round.fold_param(p).data,
            r.data,
            "requeued eager fold must match bitwise (param {p})"
        );
    }
    assert_eq!(coord.log[0].requeues, 3);
}

#[test]
fn run_round_pipelined_resumes_a_mid_round_snapshot_to_the_same_bits() {
    // mid-pipelined-round checkpoint: worker 0 has completed when the
    // coordinator is snapshotted. The eager-reduce spans are transient
    // (never checkpointed), so the restored round re-executes every
    // shard — pure execution, identical bits
    let s = src();
    let toks = tokens(6, 42);
    let dist = DistConfig { dp_workers: 2, ..DistConfig::default() };

    let mut a = dist.coordinator();
    let reference = run_round(&mut a, &s, &toks).expect("round");

    let mut b = dist.coordinator();
    b.advance_to_train().unwrap();
    b.begin_round(6).unwrap();
    let shard0 = worker::run_shard(&s, &b.assignments()[0], &toks).unwrap();
    b.complete(0, shard0.secs);
    let snap = b.snapshot();
    drop(b);

    let mut c = RoundCoordinator::restore(dist.round_cfg(), &snap).unwrap();
    let resumed = run_round_pipelined(&mut c, &s, &toks).expect("resumed pipelined round");
    assert_eq!(resumed.fold_loss().to_bits(), reference.loss.to_bits());
    for (p, r) in reference.grads.iter().enumerate() {
        assert_eq!(resumed.fold_param(p).data, r.data, "param {p}");
    }
    assert_eq!(c.round, 1);
    assert_eq!(c.log.len(), 1);
    assert_eq!(c.members[0].rounds_done, 1);
}

// ------------------------------------------------- trainer-level parity ---

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping trainer-level dist parity: run `make artifacts` first");
    }
    ok
}

#[test]
fn trainer_dist_path_is_bitwise_invariant_across_dp_and_width() {
    use alice_racs::config::RunConfig;
    use alice_racs::coordinator::Trainer;

    if !have_artifacts() {
        return;
    }
    let run = |dp: usize, width: usize| {
        pool::with_threads(width, || {
            let mut cfg = RunConfig::default().tuned_for("alice");
            cfg.artifacts = "artifacts".into();
            cfg.out_dir = format!(
                "{}/alice_racs_dist_dp{dp}_w{width}_{}",
                std::env::temp_dir().display(),
                std::process::id()
            );
            cfg.steps = 6;
            cfg.eval_every = 0;
            cfg.log_every = 1000;
            cfg.grad_accum = 4;
            cfg.hp.interval = 3;
            cfg.hp.rank = 16;
            cfg.hp.leading = 6;
            cfg.dist.dp_workers = dp;
            cfg.dist.sim = true; // dp=1 goes through the same tree reduce
            let mut tr = Trainer::new(cfg).unwrap();
            let mut losses = Vec::new();
            for _ in 0..6 {
                losses.push(tr.train_step(0.01).unwrap().to_bits());
            }
            let weights: Vec<Vec<f32>> =
                tr.params.iter().map(|p| p.as_f32().unwrap().to_vec()).collect();
            (losses, weights)
        })
    };
    let reference = run(1, 1);
    for dp in [2usize, 4] {
        for width in [1usize, 4] {
            let got = run(dp, width);
            assert_eq!(got.0, reference.0, "loss bits diverged: dp={dp} width={width}");
            assert_eq!(got.1, reference.1, "weights diverged: dp={dp} width={width}");
        }
    }
}
