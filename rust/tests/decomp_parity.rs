//! Width-parity harness for the parallel decompositions: `jacobi_eigh`
//! (all three dispatch paths — serial cyclic, Brent-Luk rounds, blocked
//! two-sided) and `mgs_qr` must produce **bitwise identical** output at
//! pool widths 1 (the serial baseline — width 1 runs every region inline
//! on the calling thread) and 4, while satisfying the usual
//! reconstruction / orthonormality / triangularity invariants on ragged
//! shapes straddling the serial↔parallel dispatch thresholds. The CI
//! matrix compiles this suite under both feature settings, so every
//! contract here is pinned on the scalar and the simd dispatch path. See
//! `linalg::decomp` for the ordering argument that makes the fan-outs
//! width-invariant. The blocked kernel is pinned through its public
//! entry (`jacobi_eigh_blocked`) at sub-dispatch sizes — the kernel is
//! size-agnostic, and its dispatch floor (n = 1024) is too slow for the
//! debug-mode suite; the `#[ignore]`d huge-n test covers the dispatch
//! route itself (run with `--release -- --ignored`).
//!
//! Also here: the eigensolver robustness regressions of ISSUE 5
//! (non-finite input guard, relative pivot thresholds on tiny-scale
//! input) at sizes that exercise the rounds path.

use alice_racs::linalg::{
    jacobi_eigh, jacobi_eigh_blocked, jacobi_eigh_serial, mgs_qr, sketched_eigh_mat,
    Mat, SketchSpec,
};
use alice_racs::util::{pool, Pcg};

fn spd(n: usize, seed: u64) -> Mat {
    let mut rng = Pcg::seeded(seed);
    let b = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    let mut a = b.matmul_nt(&b);
    for i in 0..n {
        *a.at_mut(i, i) += 0.5;
    }
    a
}

fn ortho_err(q: &Mat) -> f32 {
    q.matmul_tn(q).sub(&Mat::eye(q.cols)).max_abs()
}

/// Dimensions straddling `JACOBI_PAR_MIN_N` (96): below → serial cyclic
/// sweeps, at/above → parallel-ordered rounds, including an odd size that
/// exercises the bye slot in the round-robin schedule.
const EIGH_DIMS: &[usize] = &[12, 80, 96, 121];

/// (rows, cols) straddling `QR_PAR_MIN_WORK` (16384 trailing elements):
/// the small shapes never fan out, the large ones fan out for the early
/// steps and fall back inline as the trailing block shrinks.
const QR_SHAPES: &[(usize, usize)] = &[(30, 8), (97, 33), (200, 90), (257, 64)];

#[test]
fn eigh_bitwise_identical_across_widths() {
    for (i, &n) in EIGH_DIMS.iter().enumerate() {
        let a = spd(n, 100 + i as u64);
        let (v1, l1) = pool::with_threads(1, || jacobi_eigh(&a, 30));
        let (v4, l4) = pool::with_threads(4, || jacobi_eigh(&a, 30));
        assert_eq!(v1.data, v4.data, "eigenvectors diverge at n = {n}");
        assert_eq!(l1, l4, "eigenvalues diverge at n = {n}");
    }
}

#[test]
fn eigh_invariants_on_ragged_shapes() {
    for (i, &n) in EIGH_DIMS.iter().enumerate() {
        let a = spd(n, 100 + i as u64);
        let (v, lam) = pool::with_threads(4, || jacobi_eigh(&a, 30));
        // eigenvector orthonormality
        assert!(ortho_err(&v) < 1e-3, "ortho err at n = {n}: {}", ortho_err(&v));
        // descending eigenvalue order
        for w in lam.windows(2) {
            assert!(w[0] >= w[1] - 1e-4 * w[0].abs().max(1.0), "unsorted at n = {n}");
        }
        // reconstruction: V diag(λ) Vᵀ ≈ A
        let mut vd = v.clone();
        for r in 0..v.rows {
            for c in 0..v.cols {
                *vd.at_mut(r, c) *= lam[c];
            }
        }
        let rec = vd.matmul_nt(&v);
        let err = rec.sub(&a).max_abs();
        assert!(err < 2e-3 * a.max_abs(), "reconstruction err at n = {n}: {err}");
    }
}

/// Dimensions for the blocked kernel: 130 = two full 64-tiles + a 2-wide
/// sliver, 160 = two full tiles + a 32-wide tail — both exercise the
/// ragged tile schedule and m < 2b pivot subproblems.
const BLOCKED_DIMS: &[usize] = &[130, 160];

#[test]
fn blocked_matches_serial_eigenvalues() {
    for (i, &n) in BLOCKED_DIMS.iter().enumerate() {
        let a = spd(n, 300 + i as u64);
        let (vb, lam_b) = jacobi_eigh_blocked(&a, 30);
        let (_, lam_s) = jacobi_eigh_serial(&a, 30);
        assert!(ortho_err(&vb) < 1e-3, "blocked ortho err at n = {n}");
        let scale = lam_s[0].abs().max(1.0);
        for (got, want) in lam_b.iter().zip(&lam_s) {
            assert!(
                (got - want).abs() < 1e-2 * scale,
                "blocked λ {got} vs serial {want} at n = {n}"
            );
        }
        // reconstruction through the blocked basis
        let mut vd = vb.clone();
        for r in 0..vb.rows {
            for c in 0..vb.cols {
                *vd.at_mut(r, c) *= lam_b[c];
            }
        }
        let err = vd.matmul_nt(&vb).sub(&a).max_abs();
        assert!(err < 2e-3 * a.max_abs(), "blocked reconstruction at n = {n}: {err}");
    }
}

#[test]
fn blocked_bitwise_identical_across_widths() {
    for (i, &n) in BLOCKED_DIMS.iter().enumerate() {
        let a = spd(n, 300 + i as u64);
        // parity needs the full tile schedule, not convergence — 6
        // sweeps keep the debug-mode suite fast
        let (v1, l1) = pool::with_threads(1, || jacobi_eigh_blocked(&a, 6));
        let (v4, l4) = pool::with_threads(4, || jacobi_eigh_blocked(&a, 6));
        assert_eq!(v1.data, v4.data, "blocked eigenvectors diverge at n = {n}");
        assert_eq!(l1, l4, "blocked eigenvalues diverge at n = {n}");
    }
}

/// The dispatch route itself, above the n = 1024 blocked floor. Too slow
/// for the debug-mode suite — run with
/// `cargo test --release --test decomp_parity -- --ignored`.
#[test]
#[ignore = "n above the blocked-dispatch floor; run in release with --ignored"]
fn huge_n_dispatch_is_blocked_and_width_invariant() {
    let n = 1091; // 17 tiles + a 3-wide sliver
    let a = spd(n, 400);
    // parity does not need convergence: 2 sweeps pin the full schedule
    let (v1, l1) = pool::with_threads(1, || jacobi_eigh(&a, 2));
    let (v4, l4) = pool::with_threads(4, || jacobi_eigh(&a, 2));
    assert_eq!(v1.data, v4.data, "dispatch-level blocked V diverges");
    assert_eq!(l1, l4, "dispatch-level blocked λ diverges");
    // and the dispatch really is the blocked kernel
    let (vb, lb) = jacobi_eigh_blocked(&a, 2);
    assert_eq!(v1.data, vb.data);
    assert_eq!(l1, lb);
}

#[test]
fn non_finite_input_does_not_panic_any_path() {
    // ISSUE 5 regression: one blown-up entry used to panic
    // sort_eigh's partial_cmp().unwrap() mid-run. Serial (12), rounds
    // (121) and blocked (130, direct) paths all sanitize instead.
    for &n in &[12usize, 121] {
        let mut a = spd(n, 500 + n as u64);
        *a.at_mut(1, 3) = f32::NAN;
        *a.at_mut(5, 0) = f32::NEG_INFINITY;
        let (v, lam) = jacobi_eigh(&a, 30);
        assert!(v.is_finite(), "non-finite V at n = {n}");
        assert!(lam.iter().all(|l| l.is_finite()), "non-finite λ at n = {n}");
        assert!(ortho_err(&v) < 1e-3, "ortho err at n = {n}");
    }
    let mut a = spd(130, 501);
    *a.at_mut(7, 99) = f32::NAN;
    let (v, lam) = jacobi_eigh_blocked(&a, 30);
    assert!(v.is_finite() && lam.iter().all(|l| l.is_finite()));
    assert!(ortho_err(&v) < 1e-3);
}

#[test]
fn tiny_scale_spd_converges_on_the_rounds_path() {
    // ISSUE 5 regression: entries ~1e-12 sat below the old absolute
    // pivot cutoff — whole refreshes no-opped and returned a stale
    // basis. Relative thresholds must rotate exactly like unit scale.
    let n = 121;
    let a = spd(n, 502).scale(1e-12);
    let (v, lam) = jacobi_eigh(&a, 30);
    assert!(ortho_err(&v) < 1e-3);
    assert!(
        v.sub(&Mat::eye(n)).max_abs() > 0.1,
        "tiny-scale refresh must actually rotate the basis"
    );
    let mut vd = v.clone();
    for r in 0..n {
        for c in 0..n {
            *vd.at_mut(r, c) *= lam[c];
        }
    }
    let err = vd.matmul_nt(&v).sub(&a).max_abs();
    assert!(err < 2e-3 * a.max_abs(), "tiny-scale reconstruction err {err}");
}

// ----------------------------------------------------- sketched refresh ----
// ISSUE 6: the randomized range finder must honor the same bitwise
// width-invariance contract as the decompositions it composes (serial Ω
// draw + width-invariant matmul/mgs_qr/serial-Jacobi stages), recover
// the planted leading subspace on low-rank-plus-noise operators, and
// inherit the non-finite sanitize guard at its own entry.

fn sketch_spec(rank: usize) -> SketchSpec {
    SketchSpec { rank, oversample: 4, power_iters: 2, sweeps: 30 }
}

/// Planted low-rank-plus-noise GGᵀ: r strong directions over a weak
/// isotropic floor — the gradient-covariance shape the sketch targets.
fn planted(n: usize, r: usize, seed: u64) -> Mat {
    let mut rng = Pcg::seeded(seed);
    let b = Mat::from_vec(n, r, rng.normal_vec(n * r, 1.0));
    let e = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    b.matmul_nt(&b).scale(4.0).add(&e.matmul_nt(&e).scale(1e-3 / n as f32))
}

/// min over the r principal angles of cos²∠(span Ue, span Us), via the
/// smallest eigenvalue of (UeᵀUs)ᵀ(UeᵀUs).
fn min_cos2(ue: &Mat, us: &Mat) -> f32 {
    let m = ue.matmul_tn(us);
    let (_, lam) = jacobi_eigh_serial(&m.matmul_tn(&m), 30);
    *lam.last().unwrap()
}

#[test]
fn sketch_bitwise_identical_across_widths() {
    // sizes straddling both the QR fan-out and the eigh dispatch
    // thresholds of the stages the sketch composes
    for (i, &n) in [80usize, 121, 200].iter().enumerate() {
        let a = spd(n, 600 + i as u64);
        let r1 = pool::with_threads(1, || sketched_eigh_mat(&a, None, &sketch_spec(12), 42));
        let r4 = pool::with_threads(4, || sketched_eigh_mat(&a, None, &sketch_spec(12), 42));
        assert_eq!(r1.0.data, r4.0.data, "sketched basis diverges at n = {n}");
        assert_eq!(r1.1, r4.1, "sketched λ diverge at n = {n}");
    }
}

#[test]
fn sketch_recovers_planted_subspace() {
    let (n, r) = (150usize, 8usize);
    let a = planted(n, r, 700);
    let (ue, _) = jacobi_eigh(&a, 30);
    let ue = ue.take_cols(r);
    let (us, lam) = sketched_eigh_mat(&a, None, &sketch_spec(r), 7);
    assert_eq!((us.rows, us.cols), (n, r));
    assert!(ortho_err(&us) < 1e-3);
    assert!(lam.iter().all(|l| l.is_finite()));
    let c2 = min_cos2(&ue, &us);
    assert!(
        c2 > 0.98,
        "sketch-vs-exact principal angles too wide: min cos² = {c2}"
    );
}

#[test]
fn sketch_warm_start_tracks_a_drifting_operator() {
    // warm-starting from the previous basis must not hurt: re-sketching a
    // slightly drifted operator from the old basis still recovers the
    // planted subspace
    let (n, r) = (120usize, 6usize);
    let a0 = planted(n, r, 701);
    let (u0, _) = sketched_eigh_mat(&a0, None, &sketch_spec(r), 8);
    let drift = planted(n, r, 702).scale(0.05);
    let a1 = a0.add(&drift);
    let (u1, _) = sketched_eigh_mat(&a1, Some(&u0), &sketch_spec(r), 9);
    let (ue, _) = jacobi_eigh(&a1, 30);
    let c2 = min_cos2(&ue.take_cols(r), &u1);
    assert!(c2 > 0.97, "warm-started sketch lost the subspace: {c2}");
}

#[test]
fn sketch_sanitizes_non_finite_operator_entry() {
    // the sketch path's analogue of the solver entry guard: a poisoned
    // operator (and a poisoned warm-start basis) must yield a finite
    // orthonormal basis, never a panic
    let mut a = spd(121, 703);
    *a.at_mut(2, 77) = f32::NAN;
    *a.at_mut(100, 5) = f32::NEG_INFINITY;
    let mut warm = Mat::from_fn(121, 12, |i, j| if i == j { 1.0 } else { 0.0 });
    *warm.at_mut(0, 3) = f32::NAN;
    let (u, lam) = sketched_eigh_mat(&a, Some(&warm), &sketch_spec(12), 10);
    assert!(u.is_finite(), "sketched basis must be finite");
    assert!(lam.iter().all(|l| l.is_finite()));
    assert!(ortho_err(&u) < 1e-3);
}

#[test]
fn qr_bitwise_identical_across_widths() {
    for (i, &(m, r)) in QR_SHAPES.iter().enumerate() {
        let mut rng = Pcg::seeded(200 + i as u64);
        let a = Mat::from_vec(m, r, rng.normal_vec(m * r, 1.0));
        let q1 = pool::with_threads(1, || mgs_qr(&a));
        let q4 = pool::with_threads(4, || mgs_qr(&a));
        assert_eq!(q1.data, q4.data, "Q diverges at {m}x{r}");
    }
}

#[test]
fn qr_invariants_on_ragged_shapes() {
    for (i, &(m, r)) in QR_SHAPES.iter().enumerate() {
        let mut rng = Pcg::seeded(200 + i as u64);
        let a = Mat::from_vec(m, r, rng.normal_vec(m * r, 1.0));
        let q = pool::with_threads(4, || mgs_qr(&a));
        // orthonormality
        let oerr = ortho_err(&q);
        assert!(oerr < 1e-3, "ortho err at {m}x{r}: {oerr}");
        // triangularity: R = Qᵀ A must be upper triangular (column spans
        // are progressive for full-rank random input)
        let rm = q.matmul_tn(&a);
        let scale = 1.0 + rm.max_abs();
        for row in 1..rm.rows {
            for col in 0..row {
                let x = rm.at(row, col).abs();
                assert!(
                    x < 1e-3 * scale,
                    "R[{row}][{col}] = {x} not triangular at {m}x{r}"
                );
            }
        }
    }
}

#[test]
fn width_parity_holds_under_nested_fanout() {
    // the trainer runs decompositions *inside* per-layer pool tasks; the
    // bitwise contract must survive that nesting
    let a = spd(121, 7);
    let mut rng = Pcg::seeded(9);
    let g = Mat::from_vec(200, 90, rng.normal_vec(200 * 90, 1.0));
    let baseline = pool::with_threads(1, || (jacobi_eigh(&a, 20), mgs_qr(&g)));
    let nested = pool::with_threads(4, || {
        let mut out: Vec<Option<((Mat, Vec<f32>), Mat)>> = vec![None, None];
        pool::map_mut(&mut out, |_, slot| {
            *slot = Some((jacobi_eigh(&a, 20), mgs_qr(&g)));
        });
        out
    });
    for got in nested.into_iter().flatten() {
        assert_eq!(baseline.0 .0.data, got.0 .0.data, "nested eigh V diverges");
        assert_eq!(baseline.0 .1, got.0 .1, "nested eigh λ diverges");
        assert_eq!(baseline.1.data, got.1.data, "nested QR diverges");
    }
}
