//! Serving determinism contract: batching is scheduling, never numerics.
//!
//! * Batched scores are bitwise identical to scoring alone — at pool
//!   widths {1, 4}, across bucket sizes (CI widens the sweep via
//!   `AR_SERVE_BUCKETS`), through the open-loop queue under a
//!   multi-producer chaos burst, and over TCP.
//! * A checkpoint served through `Checkpoint::load_model` scores the
//!   in-trainer eval stream to the bitwise-identical mean loss, with the
//!   optimizer state-bytes gauge at 0 (artifact-gated, like the other
//!   trainer-level suites).

use std::time::Duration;

use alice_racs::obs;
use alice_racs::serve::{
    queue, run_client, score_batched, score_digest, serve_loop, synthetic_requests,
    BatchPolicy, Request, ScoreSource, SyntheticScoreSource, TcpServer,
};
use alice_racs::util::pool;

/// Bucket sizes to sweep — CI's serve matrix cell sets `AR_SERVE_BUCKETS`
/// to a wider list than the local default.
fn bucket_sweep() -> Vec<usize> {
    let parsed: Vec<usize> = std::env::var("AR_SERVE_BUCKETS")
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .filter(|&b| b > 0)
                .collect()
        })
        .unwrap_or_default();
    if parsed.is_empty() {
        vec![1, 4, 16]
    } else {
        parsed
    }
}

#[test]
fn batched_equals_sequential_bitwise_across_widths_and_buckets() {
    let src = SyntheticScoreSource { work: 0 };
    let reqs = synthetic_requests(23, 2, 16, 997, 0x5eed);
    let direct: Vec<u32> = reqs
        .iter()
        .map(|r| src.score(r.id, &r.tokens).unwrap().to_bits())
        .collect();
    for width in [1, 4] {
        for bucket in bucket_sweep() {
            let scores =
                pool::with_threads(width, || score_batched(&src, &reqs, bucket)).unwrap();
            let bits: Vec<u32> = scores.iter().map(|s| s.to_bits()).collect();
            assert_eq!(bits, direct, "width {width}, bucket {bucket}");
        }
    }
}

#[test]
fn open_loop_chaos_burst_drops_and_duplicates_nothing() {
    const PRODUCERS: usize = 4;
    const PER: usize = 32;
    let src = SyntheticScoreSource { work: 0 };
    // disjoint id ranges per producer; every (id, tokens) pair is known
    // up front so responses can be checked bitwise against direct scores
    let all: Vec<Vec<Request>> = (0..PRODUCERS)
        .map(|p| {
            synthetic_requests(PER, 1, 8, 997, 0xc4a0 + p as u64)
                .into_iter()
                .enumerate()
                .map(|(i, mut r)| {
                    r.id = (p * 100 + i) as u64;
                    r
                })
                .collect()
        })
        .collect();
    let (ingress, q) = queue();
    let producers: Vec<_> = all
        .iter()
        .cloned()
        .enumerate()
        .map(|(p, reqs)| {
            let ingress = ingress.clone();
            std::thread::spawn(move || {
                for (i, r) in reqs.into_iter().enumerate() {
                    // jittered bursts: arrival pattern varies, results must not
                    if (i + p) % 7 == 0 {
                        std::thread::sleep(Duration::from_micros(300));
                    }
                    ingress.submit(r.id, r.tokens).unwrap();
                }
            })
        })
        .collect();
    drop(ingress);
    let policy = BatchPolicy {
        max_batch: 5,
        max_wait: Duration::from_millis(1),
        max_queue_depth: 0,
    };
    let resps = serve_loop(&src, &policy, q).unwrap();
    for h in producers {
        h.join().unwrap();
    }
    assert_eq!(resps.len(), PRODUCERS * PER);
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    let mut want: Vec<u64> = all.iter().flatten().map(|r| r.id).collect();
    want.sort_unstable();
    assert_eq!(ids, want, "every request answered exactly once");
    for r in &resps {
        let req = &all[r.id as usize / 100][r.id as usize % 100];
        let direct = src.score(req.id, &req.tokens).unwrap();
        assert_eq!(r.score.to_bits(), direct.to_bits(), "id {}", r.id);
    }
}

#[test]
fn tcp_roundtrip_is_bitwise_and_width_invariant() {
    let n = 17;
    let reqs = synthetic_requests(n, 1, 8, 997, 0x7c9);
    let mut digests = Vec::new();
    for width in [1usize, 4] {
        let mut server =
            TcpServer::bind("127.0.0.1:0", "serve-parity").unwrap();
        let addr = server.local_addr().to_string();
        let handle = std::thread::spawn(move || {
            let src = SyntheticScoreSource { work: 0 };
            let policy = BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_queue_depth: 0,
            };
            pool::with_threads(width, || {
                server.serve(&src, &policy, n, Duration::from_secs(30))
            })
            .unwrap()
        });
        let resps = run_client(&addr, "serve-parity", &reqs).unwrap();
        let report = handle.join().unwrap();
        assert_eq!(report.served, n);
        assert_eq!(resps.len(), n);
        let src = SyntheticScoreSource { work: 0 };
        for r in &resps {
            let direct = src.score(r.id, &reqs[r.id as usize].tokens).unwrap();
            assert_eq!(r.score.to_bits(), direct.to_bits(), "width {width}, id {}", r.id);
        }
        digests.push(score_digest(&resps));
    }
    assert_eq!(digests[0], digests[1], "pool width must not change wire scores");
}

// ------------------------------------------------- artifact-gated below ---

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping trainer-level serve parity: run `make artifacts` first");
    }
    ok
}

#[test]
fn load_model_scoring_matches_in_trainer_eval_bitwise() {
    use alice_racs::config::RunConfig;
    use alice_racs::coordinator::{Checkpoint, Trainer};
    use alice_racs::data::{CorpusConfig, SyncBatcher};
    use alice_racs::runtime::HostTensor;

    if !have_artifacts() {
        return;
    }
    let mut cfg = RunConfig::default().tuned_for("adam");
    cfg.artifacts = "artifacts".into();
    cfg.out_dir = format!(
        "{}/alice_racs_test_serve_{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    cfg.steps = 6;
    cfg.eval_every = 0;
    cfg.log_every = 1000;
    let mix = cfg.corpus_mix;
    let corpus_seed = cfg.corpus_seed;
    let mut tr = Trainer::new(cfg).unwrap();
    for _ in 0..6 {
        tr.train_step(0.01).unwrap();
    }
    let ck = tr.checkpoint();
    let nb = 6;
    let ev = tr.eval(nb).unwrap();
    let eval_seed = tr.eval_seed();
    // the serve process never holds a trainer: drop it, zero the ledger,
    // and demand the state-bytes gauge stays 0 through load + scoring
    drop(tr);
    obs::reset_all();
    let path = std::env::temp_dir()
        .join(format!("serve_parity_{}.ckpt", std::process::id()));
    ck.save(&path).unwrap();
    let model = Checkpoint::load(&path).unwrap().load_model("artifacts").unwrap();
    let _ = std::fs::remove_file(&path);
    let (b, s) = model.block_shape();
    let corpus = CorpusConfig {
        vocab: model.manifest().model.vocab,
        mix,
        seed: corpus_seed,
        ..Default::default()
    };
    // regenerate the trainer's eval stream and serve it as requests
    let mut batcher = SyncBatcher::new(corpus, b, s, eval_seed);
    let reqs: Vec<Request> = (0..nb)
        .map(|i| Request {
            id: i as u64,
            tokens: HostTensor::i32(vec![b, s], batcher.next()),
        })
        .collect();
    for width in [1, 4] {
        let scores =
            pool::with_threads(width, || score_batched(&*model, &reqs, 2)).unwrap();
        let mut acc = 0.0f32;
        for sc in &scores {
            acc += *sc;
        }
        let mean = acc / nb as f32;
        assert_eq!(
            mean.to_bits(),
            ev.to_bits(),
            "served eval mean must be bitwise the trainer's (width {width})"
        );
    }
    assert_eq!(
        obs::STATE_BYTES.get(),
        0,
        "a serve process must allocate zero optimizer state"
    );
}
