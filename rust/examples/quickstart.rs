//! Quickstart: load the AOT artifacts, train the bundled preset with
//! Alice for 60 steps, print the loss curve.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use alice_racs::config::RunConfig;
use alice_racs::coordinator;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default().tuned_for("alice");
    cfg.artifacts = "artifacts".into();
    cfg.out_dir = "runs/quickstart".into();
    cfg.steps = 60;
    cfg.eval_every = 20;
    cfg.log_every = 5;
    cfg.hp.rank = 16;
    cfg.hp.leading = 6;
    cfg.hp.interval = 20;

    let summary = coordinator::run(cfg)?;
    println!(
        "\nquickstart done: final eval ppl {:.2} at {:.0} tokens/s",
        (summary.final_eval_loss.unwrap_or(f32::NAN) as f64).exp(),
        summary.tokens_per_sec
    );
    println!("curves: runs/quickstart/{{train,eval}}.csv");
    Ok(())
}
