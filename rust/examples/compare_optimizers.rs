//! Optimizer shoot-out on the bundled preset — a quick Table 2 preview.
//!
//! ```bash
//! cargo run --release --example compare_optimizers [-- steps]
//! ```

use alice_racs::bench::{bench_cfg, run_one, TablePrinter};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);
    let opts = ["sgd", "adam", "galore", "racs", "alice"];
    println!("comparing {opts:?} for {steps} steps each…\n");
    let mut table = TablePrinter::new(&["optimizer", "final eval ppl", "tokens/s"]);
    let mut results = Vec::new();
    for opt in opts {
        let mut cfg = bench_cfg(opt, "compare", steps);
        cfg.out_dir = format!("runs/compare/{opt}");
        let s = run_one(cfg)?;
        table.row(vec![
            opt.into(),
            format!("{:.2}", (s.final_eval_loss.unwrap_or(f32::NAN) as f64).exp()),
            format!("{:.0}", s.tokens_per_sec),
        ]);
        results.push(s);
    }
    table.print();
    // the paper's headline, in miniature
    let adam = results.iter().find(|s| s.optimizer == "adam").unwrap();
    let alice = results.iter().find(|s| s.optimizer == "alice").unwrap();
    if let (Some(a), Some(b)) = (adam.final_eval_loss, alice.final_eval_loss) {
        println!(
            "\nAlice final loss {b:.4} vs Adam {a:.4} — {}",
            if b < a { "Alice wins (paper shape holds)" } else { "unexpected: check hyperparams" }
        );
    }
    Ok(())
}
