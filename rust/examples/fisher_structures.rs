//! The paper's framework in action: solve Eq. (2) for every structural
//! family on the same gradient stream and print the Frobenius
//! approximation error — the generality ladder of Table 1 — plus the
//! corresponding square-root NGD updates.
//!
//! ```bash
//! cargo run --release --example fisher_structures
//! ```

use alice_racs::bench::TablePrinter;
use alice_racs::fisher::{objective, solve, Structure};
use alice_racs::linalg::{vec_cols, Mat};
use alice_racs::util::Pcg;

fn dense_fim(grads: &[Mat]) -> Mat {
    let mn = grads[0].rows * grads[0].cols;
    let mut f = Mat::zeros(mn, mn);
    for g in grads {
        let v = vec_cols(g);
        for i in 0..mn {
            for j in 0..mn {
                f.data[i * mn + j] += v[i] * v[j] / grads.len() as f32;
            }
        }
    }
    f
}

fn main() {
    let (m, n, k) = (6usize, 8usize, 40usize);
    let mut rng = Pcg::seeded(2025);
    // correlated gradient stream (shared left factor) so structure matters
    let base = Mat::from_vec(m, m, rng.normal_vec(m * m, 1.0));
    let grads: Vec<Mat> = (0..k)
        .map(|_| base.matmul(&Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0))))
        .collect();
    let f = dense_fim(&grads);
    let f_norm = f.fro_norm_sq();

    println!("layer {m}x{n}, {k} gradient samples, ‖F‖²_F = {f_norm:.1}\n");
    let mut table = TablePrinter::new(&[
        "structure (paper section)", "optimizer", "‖F̃−F‖²_F", "relative",
    ]);
    let cases = [
        (Structure::Diag, "Diag_v(v) (Prop. 1)", "Adam"),
        (Structure::Normalization, "S ⊗ Iₘ (Prop. 2)", "column norm."),
        (Structure::Whitening, "Iₙ ⊗ M (Prop. 2)", "whitening"),
        (Structure::TwoSidedDiag, "S ⊗ Q (Prop. 3)", "RACS"),
        (Structure::KronSqrt, "Rₙ^½ ⊗ Lₘ^½ (Thm 3.1)", "Shampoo"),
        (Structure::BlockDiagSharedEig, "Diag_B(UDᵢUᵀ) (Thm 3.2)", "Eigen-Adam"),
    ];
    for (s, label, opt) in cases {
        let sol = solve(s, &grads);
        let err = objective(&sol.assemble(m, n), &f);
        table.row(vec![
            label.into(),
            opt.into(),
            format!("{err:.1}"),
            format!("{:.3}", err / f_norm),
        ]);
    }
    table.print();

    // show the square-root NGD updates those solutions induce
    println!("\nsquare-root NGD updates on a fresh gradient (max |Δ|):");
    let g = base.matmul(&Mat::from_vec(m, n, rng.normal_vec(m * n, 1.0)));
    for (s, label, _) in cases {
        let sol = solve(s, &grads);
        let upd = sol.sqrt_ngd(&g);
        println!("  {label:<28} -> {:.4}", upd.max_abs());
    }
    println!(
        "\nReading: more general structures (down the table) fit F better; \
         the paper's design question is how much of that generality you \
         can afford — RACS picks S ⊗ Q, Alice makes Diag_B(UDᵢUᵀ) \
         affordable via the low-rank extension."
    );
}
