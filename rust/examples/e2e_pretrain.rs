//! End-to-end pre-training driver — the full-system validation run
//! (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Trains the AOT-lowered transformer for several hundred steps on the
//! synthetic corpus with a configurable optimizer, logging the loss curve,
//! eval perplexity, throughput, and the coordinator phase profile. All
//! three layers are exercised: Pallas kernels (inside the lowered HLO),
//! the JAX model graph, and the rust coordinator.
//!
//! ```bash
//! make artifacts                       # nano preset by default
//! cargo run --release --example e2e_pretrain -- --opt alice --steps 300
//! # bigger model (regenerates artifacts for the `small`/`large` preset):
//! make artifacts PRESET=small && cargo run --release --example e2e_pretrain
//! ```

use alice_racs::cli::{config_from_args, Args};
use alice_racs::coordinator::{run_with, Trainer};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let mut cfg = config_from_args(&args)?;
    if args.get("opt").is_none() {
        cfg = cfg.tuned_for("alice");
    }
    if args.get("steps").is_none() {
        cfg.steps = 300;
    }
    if args.get("out").is_none() {
        cfg.out_dir = format!("runs/e2e/{}", cfg.optimizer);
    }
    cfg.eval_every = cfg.eval_every.min(cfg.steps / 6).max(1);
    cfg.log_every = 10;
    cfg.hp.rank = cfg.hp.rank.min(16);
    cfg.hp.interval = cfg.hp.interval.min(50);

    let mut trainer = Trainer::new(cfg.clone())?;
    let model = trainer.engine.manifest.model.clone();
    println!(
        "e2e: preset {} ({} params), optimizer {}, {} steps, batch {}x{}",
        model.preset, model.num_params, cfg.optimizer, cfg.steps, model.batch, model.seq
    );

    let summary = run_with(&mut trainer)?;

    let first = summary.eval_history.first();
    let last = summary.eval_history.last();
    println!("\n==== E2E SUMMARY ====");
    println!("optimizer           : {}", summary.optimizer);
    println!("steps               : {}", cfg.steps);
    println!("tokens              : {}", summary.tokens);
    println!("throughput          : {:.0} tokens/s", summary.tokens_per_sec);
    println!("final train loss    : {:.4}", summary.last_train_loss);
    if let (Some(&(s0, l0)), Some(&(s1, l1))) = (first, last) {
        println!("eval loss           : {l0:.4} (step {s0}) → {l1:.4} (step {s1})");
        println!("eval ppl            : {:.2} → {:.2}", (l0 as f64).exp(), (l1 as f64).exp());
        assert!(l1 < l0, "e2e run must improve eval loss");
    }
    println!("loss curve          : {}/train.csv", cfg.out_dir);
    println!("phase profile:\n{}", trainer.profile.report());
    Ok(())
}
